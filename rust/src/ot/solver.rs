//! The Sinkhorn solver driver: the L3 iteration loop over backend ops.
//!
//! Rust owns everything the GPU library keeps in Python: schedule selection
//! (paper section H.2.4 crossover), epsilon annealing (section H.4),
//! convergence control, and the prepared-call hot path.  The loop is
//! backend-agnostic: the same driver runs on the native tiled-LSE backend
//! and (with `--features pjrt`) on precompiled HLO artifacts.
//!
//! On top of the loop sits the composable policy layer
//! ([`super::strategy::SolveStrategy`]): dual initializers, staged epsilon
//! annealing and the truncated-Newton switch-over.  The default `plain`
//! strategy runs the legacy loop bit-for-bit.

use std::time::Instant;

use anyhow::Result;

use crate::coordinator::router::{BucketCtx, Router};
use crate::runtime::{ComputeBackend, PreparedCall, Tensor};

use super::cost::dual_cost;
use super::problem::{BatchedProblem, OtProblem};
use super::strategy::{anneal, newton, SolveStrategy};

/// Update schedule (paper eq. 2-3 vs eq. 4-5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// Gauss-Seidel (OTT-style): f from g, then g from the new f.
    Alternating,
    /// Jacobi half-step averaging (GeomLoss-style): both from old values.
    Symmetric,
    /// Paper Table 18 crossover: alternating for large n*d, symmetric below.
    Auto,
}

impl Schedule {
    pub fn parse(s: &str) -> Schedule {
        match s {
            "alternating" => Schedule::Alternating,
            "symmetric" => Schedule::Symmetric,
            _ => Schedule::Auto,
        }
    }

    /// Resolve Auto at a concrete problem size.  The paper's wall-clock
    /// crossover (Table 18) sits near n*d ~ 2*10^7 on A100; below it the
    /// fused symmetric kernel wins on launch overhead, above it the
    /// alternating half-steps win on throughput.
    pub fn resolve(self, n: usize, m: usize, d: usize) -> Schedule {
        match self {
            Schedule::Auto => {
                if n.max(m) * d >= (1 << 21) {
                    Schedule::Alternating
                } else {
                    Schedule::Symmetric
                }
            }
            s => s,
        }
    }

    fn step_op(self) -> &'static str {
        match self {
            Schedule::Alternating => "alternating_step",
            Schedule::Symmetric => "symmetric_step",
            Schedule::Auto => unreachable!("resolve() first"),
        }
    }

    fn fused_op(self, k: usize) -> String {
        match self {
            Schedule::Alternating => format!("k{k}_alternating"),
            Schedule::Symmetric => format!("k{k}_symmetric"),
            Schedule::Auto => unreachable!("resolve() first"),
        }
    }
}

/// Iteration-loop configuration for [`SinkhornSolver`].
#[derive(Debug, Clone)]
pub struct SolverConfig {
    /// Maximum Sinkhorn iterations (total across all annealing stages).
    pub max_iters: usize,
    /// Stop when the sup-norm potential change drops below this.
    pub tol: f32,
    /// Update schedule (alternating / symmetric / auto crossover).
    pub schedule: Schedule,
    /// Use the fused k-step op (one dispatch per k iterations) when far
    /// from tolerance.
    pub use_fused: bool,
    /// Epsilon annealing factor in (0, 1]; 1.0 disables (section H.4: 0.9).
    /// This is the legacy one-iteration-per-level ladder; it is superseded
    /// (and ignored) when the strategy carries a staged [`anneal`] schedule.
    pub anneal_factor: f32,
    /// Hot-path optimization: freeze the static inputs (points, weights)
    /// in a [`PreparedCall`] once per solve so the iteration loop streams
    /// only the evolving potentials.  `false` selects the naive
    /// rebuild-every-iteration path (kept for before/after measurement).
    pub prepared: bool,
    /// The solve policy: dual init + staged annealing + Newton hand-off.
    /// [`SolveStrategy::plain`] (the default) is the legacy loop,
    /// bit-for-bit.
    pub strategy: SolveStrategy,
    /// Externally supplied starting duals (shifted, lengths n / m),
    /// taking precedence over the strategy's initializer when present.
    /// This is how the serving layer's warm-start cache injects the
    /// previous solve of the same instance; `None` (the default) leaves
    /// the solve bitwise identical to the pre-cache path.  Mismatched
    /// lengths are ignored, falling back to the strategy initializer.
    pub warm_start: Option<Potentials>,
}

impl Default for SolverConfig {
    fn default() -> Self {
        Self {
            max_iters: 1000,
            tol: 1e-4,
            schedule: Schedule::Alternating,
            use_fused: true,
            anneal_factor: 1.0,
            prepared: true,
            strategy: SolveStrategy::plain(),
            warm_start: None,
        }
    }
}

impl SolverConfig {
    /// Build from the launcher's JSON `solver` section.  Errors when the
    /// section's strategy spec does not parse.
    pub fn from_section(s: &crate::config::SolverSection) -> Result<Self> {
        Ok(Self {
            max_iters: s.max_iters,
            tol: s.tol,
            schedule: Schedule::parse(&s.schedule),
            use_fused: s.use_fused,
            anneal_factor: s.anneal_factor,
            prepared: true,
            strategy: SolveStrategy::parse(&s.strategy)?,
            warm_start: None,
        })
    }

    /// A budget-pinned config: exactly `iters` iterations, no tolerance
    /// check (paper benchmarks fix 10).
    pub fn fixed_iters(iters: usize, schedule: Schedule) -> Self {
        Self { max_iters: iters, tol: 0.0, schedule, ..Self::default() }
    }
}

/// Shifted dual potentials (Prop. 1): fhat = f - |x|^2, ghat = g - |y|^2.
#[derive(Debug, Clone)]
pub struct Potentials {
    /// Shifted source potential, length n.
    pub fhat: Vec<f32>,
    /// Shifted target potential, length m.
    pub ghat: Vec<f32>,
}

/// One entry of a solve's per-stage trajectory ([`SolveReport::stages`]).
#[derive(Debug, Clone)]
pub struct StageTrace {
    /// `"sinkhorn"` or `"newton"`.
    pub kind: &'static str,
    /// Regularization strength this stage ran at.
    pub eps: f32,
    /// Iterations (Sinkhorn) or accepted outer steps (Newton) spent here.
    pub iters: usize,
    /// Sup-norm potential delta (Sinkhorn) or L1 marginal error (Newton)
    /// at stage exit.
    pub final_delta: f32,
    /// Total CG iterations (Newton stages; 0 otherwise).
    pub cg_iters: usize,
}

/// What a solve did: iterations, convergence, cost, timing, routing.
#[derive(Debug, Clone)]
pub struct SolveReport {
    /// Iterations actually run (Sinkhorn iterations + Newton outer steps,
    /// summed across all stages).
    pub iters: usize,
    /// Last convergence measure observed: the sup-norm potential change
    /// for Sinkhorn-final solves, the L1 marginal error when a Newton
    /// polish converged the solve.
    pub final_delta: f32,
    /// The regularized OT cost `OT_eps` (dual objective).
    pub cost: f64,
    /// True when the solve reached its tolerance in budget (Sinkhorn
    /// delta below `tol`, or the Newton polish below its marginal
    /// tolerance).
    pub converged: bool,
    /// Wall-clock time of the solve.
    pub wall: std::time::Duration,
    /// The schedule actually used (Auto resolved).
    pub schedule: Schedule,
    /// The (n, m, d) bucket the problem routed into.
    pub bucket: (usize, usize, usize),
    /// Per-stage trajectory: one entry per annealing stage, plus the
    /// Newton polish and any post-fallback Sinkhorn resume.  Plain solves
    /// have exactly one entry.
    pub stages: Vec<StageTrace>,
    /// Measured IO/work counters for this solve (the delta of the
    /// backend's cumulative [`ComputeBackend::io_stats`] across the
    /// solve).  All-zeros when the backend does not measure or counters
    /// are gated off; note the `pool_*` nanos are pool-wide wall time, so
    /// concurrent solves on a shared pool each see the union interval.
    pub io: crate::obs::IoStats,
}

/// The L3 iteration-loop driver: schedules backend step ops, controls
/// convergence and eps-annealing, and reports cost.  Backend-agnostic —
/// the same driver runs on the native tiled-LSE backend and on
/// precompiled HLO artifacts.
pub struct SinkhornSolver<'e> {
    backend: &'e dyn ComputeBackend,
    router: Router,
    /// The iteration-loop configuration this solver was built with.
    pub cfg: SolverConfig,
}

impl<'e> SinkhornSolver<'e> {
    /// A solver on `backend` with the given loop configuration.
    pub fn new(backend: &'e dyn ComputeBackend, cfg: SolverConfig) -> Self {
        let router = backend.router();
        Self { backend, router, cfg }
    }

    /// The backend's router (exact-fit on native, bucketed on PJRT).
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// The backend this solver dispatches to.
    pub fn backend(&self) -> &'e dyn ComputeBackend {
        self.backend
    }

    /// Solve: route to a bucket, pad if bucketed, iterate to tolerance or
    /// budget.  This is the top-level entry point for one EOT solve.
    ///
    /// # Example
    ///
    /// ```
    /// use flash_sinkhorn::prelude::*;
    ///
    /// let backend = NativeBackend::default();
    /// let (x, y) = (uniform_cloud(64, 4, 1), uniform_cloud(48, 4, 2));
    /// let prob = OtProblem::uniform(x, y, 64, 48, 4, 0.2).unwrap();
    /// let solver = SinkhornSolver::new(&backend, SolverConfig::default());
    /// let (potentials, report) = solver.solve(&prob).unwrap();
    /// assert!(report.converged);
    /// assert!(report.cost.is_finite());
    /// assert_eq!(potentials.fhat.len(), 64);
    /// assert_eq!(potentials.ghat.len(), 48);
    /// ```
    pub fn solve(&self, prob: &OtProblem) -> Result<(Potentials, SolveReport)> {
        let ctx = BucketCtx::new(&self.router, prob)?;
        self.solve_in_ctx(prob, &ctx)
    }

    /// Solve inside a pre-built context (reused by divergence / OTDD).
    pub fn solve_in_ctx(
        &self,
        prob: &OtProblem,
        ctx: &BucketCtx,
    ) -> Result<(Potentials, SolveReport)> {
        let t0 = Instant::now();
        let io0 = self.backend.io_stats();
        let schedule = self.cfg.schedule.resolve(prob.n, prob.m, prob.d);
        let k_fused = self.backend.k_fused();
        let strategy = &self.cfg.strategy;

        // dual init: an externally injected warm start (the serving
        // layer's cache) wins; otherwise zeros (unshifted f = g = 0 =>
        // fhat = -alpha, ghat = -beta) or a strategy warm start
        let (fhat0, ghat0) = match &self.cfg.warm_start {
            Some(w) if w.fhat.len() == prob.n && w.ghat.len() == prob.m => {
                (w.fhat.clone(), w.ghat.clone())
            }
            _ => strategy.init.shifted_duals(prob),
        };
        let mut f = Tensor::vector(padded(&fhat0, ctx.bucket.n));
        let mut g = Tensor::vector(padded(&ghat0, ctx.bucket.m));

        let step_key = ctx.key(schedule.step_op());
        let fused_key = ctx.key(&schedule.fused_op(k_fused));
        let have_fused = self.cfg.use_fused && self.backend.has(&fused_key);

        // one prepared call per op: statics (x, y, a, b) frozen, dynamics
        // (f, g, eps) streamed per iteration.
        let prep = |key: &str| {
            PreparedCall::new(
                self.backend,
                key,
                vec![
                    Some(ctx.x.clone()),
                    Some(ctx.y.clone()),
                    None, // fhat
                    None, // ghat
                    Some(ctx.a.clone()),
                    Some(ctx.b.clone()),
                    None, // eps
                ],
            )
        };
        let step_call = prep(&step_key);
        let fused_call = if have_fused { Some(prep(&fused_key)) } else { None };

        let run = |call: &PreparedCall<'_>, f: &mut Tensor, g: &mut Tensor, eps: f32| -> Result<f32> {
            let outs = if self.cfg.prepared {
                call.call(&[f.clone(), g.clone(), Tensor::scalar(eps)])?
            } else {
                // naive path: rebuild the full input list every iteration
                self.backend.call(
                    call.key(),
                    &[
                        ctx.x.clone(),
                        ctx.y.clone(),
                        f.clone(),
                        g.clone(),
                        ctx.a.clone(),
                        ctx.b.clone(),
                        Tensor::scalar(eps),
                    ],
                )?
            };
            let mut it = outs.into_iter();
            *f = it.next().ok_or_else(|| anyhow::anyhow!("step returned no f"))?;
            *g = it.next().ok_or_else(|| anyhow::anyhow!("step returned no g"))?;
            let df = it.next().ok_or_else(|| anyhow::anyhow!("step returned no df"))?.item()?;
            let dg = it.next().ok_or_else(|| anyhow::anyhow!("step returned no dg"))?.item()?;
            Ok(df.max(dg))
        };

        let mut iters = 0usize;
        let mut delta = f32::INFINITY;
        let mut stages: Vec<StageTrace> = Vec::new();

        // one Sinkhorn stage at a fixed eps, sharing the global budget
        let sinkhorn_stage =
            |eps_s: f32, tol_s: f32, f: &mut Tensor, g: &mut Tensor, iters: &mut usize| -> Result<f32> {
                let mut delta = f32::INFINITY;
                while *iters < self.cfg.max_iters && delta > tol_s {
                    if let (Some(fused), true) =
                        (&fused_call, self.cfg.max_iters - *iters >= k_fused)
                    {
                        delta = run(fused, f, g, eps_s)?;
                        *iters += k_fused;
                    } else {
                        delta = run(&step_call, f, g, eps_s)?;
                        *iters += 1;
                    }
                }
                Ok(delta)
            };

        // Stage ladder: [prob.eps] unless the strategy anneals.  The
        // legacy one-iteration-per-level H.4 ladder only runs when staged
        // annealing is off, so `anneal:1` stays bitwise `plain`.
        let eps_levels = strategy.eps_stages(prob);
        let n_levels = eps_levels.len();
        if n_levels == 1 && self.cfg.anneal_factor < 1.0 {
            let mut eps_level = prob.sq_diameter().max(prob.eps);
            while eps_level > prob.eps && iters < self.cfg.max_iters {
                run(&step_call, &mut f, &mut g, eps_level)?;
                eps_level *= self.cfg.anneal_factor;
                iters += 1;
            }
        }
        for (si, &eps_s) in eps_levels.iter().enumerate() {
            let last = si + 1 == n_levels;
            let mut tol_s = if last { self.cfg.tol } else { anneal::stage_tol(self.cfg.tol) };
            if last {
                // with a Newton hand-off configured, the final Sinkhorn
                // stage only has to reach the switch-over point
                if let Some(np) = &strategy.newton {
                    tol_s = tol_s.max(np.switch_at);
                }
            }
            let start = iters;
            delta = sinkhorn_stage(eps_s, tol_s, &mut f, &mut g, &mut iters)?;
            stages.push(StageTrace {
                kind: "sinkhorn",
                eps: eps_s,
                iters: iters - start,
                final_delta: delta,
                cg_iters: 0,
            });
        }

        // Newton polish at target eps, with a Sinkhorn resume on fallback.
        let mut newton_converged = false;
        if let Some(np) = &strategy.newton {
            let mut pot = Potentials {
                fhat: f.as_f32()?[..prob.n].to_vec(),
                ghat: g.as_f32()?[..prob.m].to_vec(),
            };
            let out = newton::polish(self.backend, ctx, &mut pot, np)?;
            iters += out.steps;
            stages.push(StageTrace {
                kind: "newton",
                eps: prob.eps,
                iters: out.steps,
                final_delta: out.final_marginal_err,
                cg_iters: out.cg_iters,
            });
            f = Tensor::vector(padded(&pot.fhat, ctx.bucket.n));
            g = Tensor::vector(padded(&pot.ghat, ctx.bucket.m));
            if out.converged {
                newton_converged = true;
                delta = out.final_marginal_err;
            } else {
                // clean fallback: finish with plain Sinkhorn on whatever
                // budget remains
                let start = iters;
                delta = sinkhorn_stage(prob.eps, self.cfg.tol, &mut f, &mut g, &mut iters)?;
                stages.push(StageTrace {
                    kind: "sinkhorn",
                    eps: prob.eps,
                    iters: iters - start,
                    final_delta: delta,
                    cg_iters: 0,
                });
            }
        }

        let pot = Potentials {
            fhat: f.as_f32()?[..prob.n].to_vec(),
            ghat: g.as_f32()?[..prob.m].to_vec(),
        };
        let cost = dual_cost(prob, &pot);
        let report = SolveReport {
            iters,
            final_delta: delta,
            cost,
            converged: delta <= self.cfg.tol || newton_converged,
            wall: t0.elapsed(),
            schedule,
            bucket: (ctx.bucket.n, ctx.bucket.m, ctx.bucket.d),
            stages,
            io: self.backend.io_stats().delta_since(&io0),
        };
        Ok((pot, report))
    }

    /// Solve `B` small problems in one fused pass over packed tiles.
    ///
    /// Packs the problems into a [`BatchedProblem`] (one NEG_INF-walled
    /// row/column between neighbours) and drives
    /// [`ComputeBackend::lse_step_batch`] in lockstep: every still-active
    /// problem runs the identical fused/single step sequence the
    /// sequential loop would have chosen at the same iteration count, and
    /// freezes in place once it reaches tolerance or budget.  Because the
    /// step choice depends only on the shared iteration counter, each
    /// problem's potentials are **bitwise identical** to a standalone
    /// [`Self::solve`] with the same warm start.
    ///
    /// `warm[p]`, when present with matching lengths, seeds problem `p`'s
    /// duals (the serving layer's per-tenant cache); otherwise the plain
    /// zeros init applies.  The config's own `warm_start` field is
    /// ignored here — it is a single-problem knob.
    ///
    /// Restrictions (the caller falls back to sequential solves when they
    /// do not hold): the strategy must be plain, the legacy anneal ladder
    /// off, and every problem must resolve to the same schedule.
    ///
    /// Per-problem `SolveReport.io` sums the backend's batched per-problem
    /// deltas, which exclude pool wall nanos (those are pool-wide and
    /// unattributable to one problem of a fused dispatch).
    pub fn solve_batch(
        &self,
        probs: &[&OtProblem],
        warm: &[Option<Potentials>],
    ) -> Result<Vec<(Potentials, SolveReport)>> {
        anyhow::ensure!(
            warm.len() == probs.len(),
            "solve_batch: {} warm entries for {} problems",
            warm.len(),
            probs.len()
        );
        anyhow::ensure!(
            self.cfg.strategy.is_plain(),
            "solve_batch supports only the plain strategy"
        );
        anyhow::ensure!(
            self.cfg.anneal_factor >= 1.0,
            "solve_batch does not support the legacy anneal ladder"
        );
        if probs.is_empty() {
            return Ok(Vec::new());
        }
        let t0 = Instant::now();
        let schedule = self.cfg.schedule.resolve(probs[0].n, probs[0].m, probs[0].d);
        for p in probs {
            anyhow::ensure!(
                self.cfg.schedule.resolve(p.n, p.m, p.d) == schedule,
                "solve_batch requires a uniform resolved schedule"
            );
        }
        let alternating = schedule == Schedule::Alternating;
        let batch = BatchedProblem::pack(probs)?;
        let b = probs.len();

        // packed dual init: walls stay 0.0 (their weights are 0.0, so the
        // kernels never read them); each segment gets its warm start when
        // the lengths match, else the plain zeros init (-alpha, -beta).
        let mut fhat = vec![0.0f32; batch.rows()];
        let mut ghat = vec![0.0f32; batch.cols()];
        for (p, prob) in probs.iter().enumerate() {
            let rr = batch.row_range(p);
            let cr = batch.col_range(p);
            match &warm[p] {
                Some(w) if w.fhat.len() == prob.n && w.ghat.len() == prob.m => {
                    fhat[rr].copy_from_slice(&w.fhat);
                    ghat[cr].copy_from_slice(&w.ghat);
                }
                _ => {
                    let (f0, g0) = self.cfg.strategy.init.shifted_duals(prob);
                    fhat[rr].copy_from_slice(&f0);
                    ghat[cr].copy_from_slice(&g0);
                }
            }
        }

        let k_fused = self.backend.k_fused();
        let have_fused = self.cfg.use_fused && self.backend.has(&schedule.fused_op(k_fused));

        let mut active = vec![true; b];
        let mut delta = vec![f32::INFINITY; b];
        let mut final_iters = vec![0usize; b];
        let mut io = vec![crate::obs::IoStats::default(); b];
        let mut iters = 0usize;
        while iters < self.cfg.max_iters && active.iter().any(|&a| a) {
            // identical step choice to the sequential loop at this count
            let k = if have_fused && self.cfg.max_iters - iters >= k_fused {
                k_fused
            } else {
                1
            };
            let outs =
                self.backend.lse_step_batch(&batch, &mut fhat, &mut ghat, &active, k, alternating)?;
            iters += k;
            for p in 0..b {
                if !active[p] {
                    continue;
                }
                delta[p] = outs[p].df.max(outs[p].dg);
                io[p].add(&outs[p].io);
                if delta[p] <= self.cfg.tol || iters >= self.cfg.max_iters {
                    active[p] = false;
                    final_iters[p] = iters;
                }
            }
        }

        let wall = t0.elapsed();
        let mut results = Vec::with_capacity(b);
        for (p, prob) in probs.iter().enumerate() {
            let pot = Potentials {
                fhat: fhat[batch.row_range(p)].to_vec(),
                ghat: ghat[batch.col_range(p)].to_vec(),
            };
            let cost = dual_cost(prob, &pot);
            results.push((
                pot,
                SolveReport {
                    iters: final_iters[p],
                    final_delta: delta[p],
                    cost,
                    converged: delta[p] <= self.cfg.tol,
                    wall,
                    schedule,
                    bucket: (prob.n, prob.m, prob.d),
                    stages: vec![StageTrace {
                        kind: "sinkhorn",
                        eps: prob.eps,
                        iters: final_iters[p],
                        final_delta: delta[p],
                        cg_iters: 0,
                    }],
                    io: io[p],
                },
            ));
        }
        Ok(results)
    }
}

/// Copy `v` into a zero-padded vector of length `len`.
fn padded(v: &[f32], len: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; len];
    out[..v.len()].copy_from_slice(v);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_parse_and_resolve() {
        assert_eq!(Schedule::parse("alternating"), Schedule::Alternating);
        assert_eq!(Schedule::parse("symmetric"), Schedule::Symmetric);
        assert_eq!(Schedule::parse("whatever"), Schedule::Auto);
        assert_eq!(Schedule::Auto.resolve(100, 100, 4), Schedule::Symmetric);
        assert_eq!(Schedule::Auto.resolve(40_000, 40_000, 128), Schedule::Alternating);
        assert_eq!(Schedule::Alternating.resolve(1, 1, 1), Schedule::Alternating);
    }

    #[test]
    fn padded_layout() {
        assert_eq!(padded(&[1.0, -2.0], 4), vec![1.0, -2.0, 0.0, 0.0]);
    }

    #[test]
    fn fixed_iter_config() {
        let cfg = SolverConfig::fixed_iters(10, Schedule::Symmetric);
        assert_eq!(cfg.max_iters, 10);
        assert_eq!(cfg.tol, 0.0);
        assert!(cfg.strategy.is_plain());
    }

    #[test]
    fn solves_on_native_backend_end_to_end() {
        let backend = crate::native::NativeBackend::default();
        let prob = OtProblem::uniform(
            crate::data::clouds::uniform_cloud(40, 3, 1),
            crate::data::clouds::uniform_cloud(50, 3, 2),
            40,
            50,
            3,
            0.2,
        )
        .unwrap();
        let solver = SinkhornSolver::new(&backend, SolverConfig::default());
        let (pot, report) = solver.solve(&prob).unwrap();
        assert!(report.converged, "delta {}", report.final_delta);
        assert_eq!(pot.fhat.len(), 40);
        assert_eq!(pot.ghat.len(), 50);
        assert_eq!(report.bucket, (40, 50, 3));
        assert!(report.cost.is_finite());
        // plain solves report exactly one Sinkhorn stage
        assert_eq!(report.stages.len(), 1);
        assert_eq!(report.stages[0].kind, "sinkhorn");
        assert_eq!(report.stages[0].iters, report.iters);
        assert_eq!(report.stages[0].eps, 0.2);
    }

    #[test]
    fn warm_start_beats_cold_and_meets_the_contract() {
        let backend = crate::native::NativeBackend::default();
        let prob = OtProblem::uniform(
            crate::data::clouds::uniform_cloud(48, 4, 7),
            crate::data::clouds::uniform_cloud(40, 4, 8),
            48,
            40,
            4,
            0.1,
        )
        .unwrap();
        let cold_solver = SinkhornSolver::new(&backend, SolverConfig::default());
        let (pot, cold) = cold_solver.solve(&prob).unwrap();
        assert!(cold.converged);

        let warm_cfg = SolverConfig { warm_start: Some(pot), ..SolverConfig::default() };
        let warm_solver = SinkhornSolver::new(&backend, warm_cfg);
        let (_, warm) = warm_solver.solve(&prob).unwrap();
        // contract: converged (final sup-norm delta <= tol) at strictly
        // fewer iterations, cost agreeing with the cold solve
        assert!(warm.converged, "warm delta {}", warm.final_delta);
        assert!(warm.final_delta <= warm_solver.cfg.tol);
        assert!(
            warm.iters < cold.iters,
            "warm {} vs cold {} iterations",
            warm.iters,
            cold.iters
        );
        assert!(
            (warm.cost - cold.cost).abs() <= 1e-4 * cold.cost.abs().max(1.0),
            "warm cost {} vs cold {}",
            warm.cost,
            cold.cost
        );
    }

    #[test]
    fn solve_batch_matches_sequential_bitwise() {
        let backend = crate::native::NativeBackend::default();
        let probs: Vec<OtProblem> = (0..3)
            .map(|i| {
                let (n, m) = (16 + 4 * i, 12 + 3 * i);
                OtProblem::uniform(
                    crate::data::clouds::uniform_cloud(n, 3, 10 + i as u64),
                    crate::data::clouds::uniform_cloud(m, 3, 20 + i as u64),
                    n,
                    m,
                    3,
                    0.15,
                )
                .unwrap()
            })
            .collect();
        let solver = SinkhornSolver::new(&backend, SolverConfig::default());
        let refs: Vec<&OtProblem> = probs.iter().collect();
        let batched = solver.solve_batch(&refs, &[None, None, None]).unwrap();
        assert_eq!(batched.len(), 3);
        for (p, prob) in probs.iter().enumerate() {
            let (pot, rep) = solver.solve(prob).unwrap();
            let (bpot, brep) = &batched[p];
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&pot.fhat), bits(&bpot.fhat), "problem {p} fhat");
            assert_eq!(bits(&pot.ghat), bits(&bpot.ghat), "problem {p} ghat");
            assert_eq!(rep.iters, brep.iters, "problem {p} iters");
            assert_eq!(rep.cost.to_bits(), brep.cost.to_bits(), "problem {p} cost");
            assert_eq!(rep.converged, brep.converged);
            assert_eq!(brep.stages.len(), 1);
        }
    }

    #[test]
    fn solve_batch_warm_start_matches_sequential_warm_start() {
        let backend = crate::native::NativeBackend::default();
        let prob = OtProblem::uniform(
            crate::data::clouds::uniform_cloud(24, 4, 31),
            crate::data::clouds::uniform_cloud(20, 4, 32),
            24,
            20,
            4,
            0.1,
        )
        .unwrap();
        let cold = SinkhornSolver::new(&backend, SolverConfig::default());
        let (pot, _) = cold.solve(&prob).unwrap();
        let warm_cfg = SolverConfig { warm_start: Some(pot.clone()), ..SolverConfig::default() };
        let (spot, srep) = SinkhornSolver::new(&backend, warm_cfg).solve(&prob).unwrap();
        let batched = cold.solve_batch(&[&prob], &[Some(pot)]).unwrap();
        let (bpot, brep) = &batched[0];
        assert_eq!(srep.iters, brep.iters);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&spot.fhat), bits(&bpot.fhat));
        assert_eq!(bits(&spot.ghat), bits(&bpot.ghat));
    }

    #[test]
    fn solve_batch_rejects_non_plain_configs() {
        let backend = crate::native::NativeBackend::default();
        let prob = OtProblem::uniform(
            crate::data::clouds::uniform_cloud(8, 2, 1),
            crate::data::clouds::uniform_cloud(8, 2, 2),
            8,
            8,
            2,
            0.3,
        )
        .unwrap();
        let anneal = SolverConfig { anneal_factor: 0.9, ..SolverConfig::default() };
        assert!(SinkhornSolver::new(&backend, anneal)
            .solve_batch(&[&prob], &[None])
            .is_err());
        let solver = SinkhornSolver::new(&backend, SolverConfig::default());
        // warm-vector length mismatch
        assert!(solver.solve_batch(&[&prob], &[]).is_err());
        // empty batch is fine
        assert!(solver.solve_batch(&[], &[]).unwrap().is_empty());
    }

    #[test]
    fn mismatched_warm_start_lengths_fall_back_to_the_initializer() {
        let backend = crate::native::NativeBackend::default();
        let prob = OtProblem::uniform(
            crate::data::clouds::uniform_cloud(32, 3, 3),
            crate::data::clouds::uniform_cloud(24, 3, 4),
            32,
            24,
            3,
            0.2,
        )
        .unwrap();
        let plain = SinkhornSolver::new(&backend, SolverConfig::default());
        let (_, base) = plain.solve(&prob).unwrap();
        // wrong-shape duals (stale bucket, foreign problem) must be ignored
        let bogus = Potentials { fhat: vec![0.0; 5], ghat: vec![0.0; 7] };
        let cfg = SolverConfig { warm_start: Some(bogus), ..SolverConfig::default() };
        let (_, report) = SinkhornSolver::new(&backend, cfg).solve(&prob).unwrap();
        assert_eq!(report.iters, base.iters, "fallback must match the cold path exactly");
        assert_eq!(report.cost.to_bits(), base.cost.to_bits());
    }
}
