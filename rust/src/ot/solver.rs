//! The Sinkhorn solver driver: the L3 iteration loop over L1/L2 artifacts.
//!
//! Rust owns everything the GPU library keeps in Python: schedule selection
//! (paper section H.2.4 crossover), epsilon annealing (section H.4),
//! convergence control, and the executable-cache hot path.  Per iteration
//! the only work outside PJRT is two f32 copies of the potentials.

use std::time::Instant;

use anyhow::Result;

use crate::coordinator::router::{BucketCtx, Router};
use crate::runtime::{Engine, Tensor};

use super::cost::dual_cost;
use super::problem::OtProblem;

/// Update schedule (paper eq. 2-3 vs eq. 4-5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// Gauss-Seidel (OTT-style): f from g, then g from the new f.
    Alternating,
    /// Jacobi half-step averaging (GeomLoss-style): both from old values.
    Symmetric,
    /// Paper Table 18 crossover: alternating for large n*d, symmetric below.
    Auto,
}

impl Schedule {
    pub fn parse(s: &str) -> Schedule {
        match s {
            "alternating" => Schedule::Alternating,
            "symmetric" => Schedule::Symmetric,
            _ => Schedule::Auto,
        }
    }

    /// Resolve Auto at a concrete problem size.  The paper's wall-clock
    /// crossover (Table 18) sits near n*d ~ 2*10^7 on A100; below it the
    /// fused symmetric kernel wins on launch overhead, above it the
    /// alternating half-steps win on throughput.
    pub fn resolve(self, n: usize, m: usize, d: usize) -> Schedule {
        match self {
            Schedule::Auto => {
                if n.max(m) * d >= (1 << 21) {
                    Schedule::Alternating
                } else {
                    Schedule::Symmetric
                }
            }
            s => s,
        }
    }

    fn step_op(self) -> &'static str {
        match self {
            Schedule::Alternating => "alternating_step",
            Schedule::Symmetric => "symmetric_step",
            Schedule::Auto => unreachable!("resolve() first"),
        }
    }

    fn fused_op(self, k: usize) -> String {
        match self {
            Schedule::Alternating => format!("k{k}_alternating"),
            Schedule::Symmetric => format!("k{k}_symmetric"),
            Schedule::Auto => unreachable!("resolve() first"),
        }
    }
}

#[derive(Debug, Clone)]
pub struct SolverConfig {
    pub max_iters: usize,
    /// Stop when the sup-norm potential change drops below this.
    pub tol: f32,
    pub schedule: Schedule,
    /// Use the fused k-step artifact (lax.scan) when far from tolerance.
    pub use_fused: bool,
    /// Epsilon annealing factor in (0, 1]; 1.0 disables (section H.4: 0.9).
    pub anneal_factor: f32,
    /// Hot-path optimization (EXPERIMENTS.md section Perf): build the
    /// static input literals (points, weights) once per solve and keep the
    /// evolving potentials as literals, so the iteration loop performs no
    /// host-side tensor copies.  `false` selects the naive per-iteration
    /// conversion path (kept for the before/after measurement).
    pub cached_literals: bool,
}

impl Default for SolverConfig {
    fn default() -> Self {
        Self {
            max_iters: 1000,
            tol: 1e-4,
            schedule: Schedule::Alternating,
            use_fused: true,
            anneal_factor: 1.0,
            cached_literals: true,
        }
    }
}

impl SolverConfig {
    pub fn from_section(s: &crate::config::SolverSection) -> Self {
        Self {
            max_iters: s.max_iters,
            tol: s.tol,
            schedule: Schedule::parse(&s.schedule),
            use_fused: s.use_fused,
            anneal_factor: s.anneal_factor,
            cached_literals: true,
        }
    }

    pub fn fixed_iters(iters: usize, schedule: Schedule) -> Self {
        Self { max_iters: iters, tol: 0.0, schedule, ..Self::default() }
    }
}

/// Shifted dual potentials (Prop. 1): fhat = f - |x|^2, ghat = g - |y|^2.
#[derive(Debug, Clone)]
pub struct Potentials {
    pub fhat: Vec<f32>,
    pub ghat: Vec<f32>,
}

#[derive(Debug, Clone)]
pub struct SolveReport {
    pub iters: usize,
    pub final_delta: f32,
    pub cost: f64,
    pub converged: bool,
    pub wall: std::time::Duration,
    pub schedule: Schedule,
    pub bucket: (usize, usize, usize),
}

pub struct SinkhornSolver<'e> {
    engine: &'e Engine,
    router: Router,
    pub cfg: SolverConfig,
}

impl<'e> SinkhornSolver<'e> {
    pub fn new(engine: &'e Engine, cfg: SolverConfig) -> Self {
        let router = Router::from_manifest(engine.manifest());
        Self { engine, router, cfg }
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Solve: route to a bucket, pad, iterate to tolerance or budget.
    pub fn solve(&self, prob: &OtProblem) -> Result<(Potentials, SolveReport)> {
        let ctx = BucketCtx::new(&self.router, prob)?;
        self.solve_in_ctx(prob, &ctx)
    }

    /// Solve inside a pre-built context (reused by divergence / OTDD).
    pub fn solve_in_ctx(&self, prob: &OtProblem, ctx: &BucketCtx) -> Result<(Potentials, SolveReport)> {
        if self.cfg.cached_literals {
            return self.solve_in_ctx_fast(prob, ctx);
        }
        let t0 = Instant::now();
        let schedule = self.cfg.schedule.resolve(prob.n, prob.m, prob.d);
        let k_fused = self.engine.manifest().k_fused;

        // init = unshifted f = g = 0  =>  fhat = -alpha, ghat = -beta.
        let mut fhat = neg_padded(&ctx.alpha, ctx.bucket.n);
        let mut ghat = neg_padded(&ctx.beta, ctx.bucket.m);

        // epsilon annealing ladder (one iteration per level).
        let mut iters = 0usize;
        let mut delta = f32::INFINITY;
        if self.cfg.anneal_factor < 1.0 {
            let mut eps_level = prob.sq_diameter().max(prob.eps);
            while eps_level > prob.eps && iters < self.cfg.max_iters {
                let (f2, g2, _, _) =
                    self.step(ctx, schedule.step_op(), &fhat, &ghat, eps_level)?;
                fhat = f2;
                ghat = g2;
                eps_level *= self.cfg.anneal_factor;
                iters += 1;
            }
        }

        // main loop at target eps.
        let fused_key = ctx.key(&schedule.fused_op(k_fused));
        let have_fused = self.cfg.use_fused && self.engine.manifest().has(&fused_key);
        while iters < self.cfg.max_iters && delta > self.cfg.tol {
            if have_fused && self.cfg.max_iters - iters >= k_fused {
                let (f2, g2, df, dg) =
                    self.call_update(&fused_key, ctx, &fhat, &ghat, prob.eps)?;
                fhat = f2;
                ghat = g2;
                delta = df.max(dg);
                iters += k_fused;
            } else {
                let (f2, g2, df, dg) =
                    self.step(ctx, schedule.step_op(), &fhat, &ghat, prob.eps)?;
                fhat = f2;
                ghat = g2;
                delta = df.max(dg);
                iters += 1;
            }
        }

        let pot = Potentials {
            fhat: fhat[..prob.n].to_vec(),
            ghat: ghat[..prob.m].to_vec(),
        };
        let cost = dual_cost(prob, &pot);
        let report = SolveReport {
            iters,
            final_delta: delta,
            cost,
            converged: delta <= self.cfg.tol,
            wall: t0.elapsed(),
            schedule,
            bucket: (ctx.bucket.n, ctx.bucket.m, ctx.bucket.d),
        };
        Ok((pot, report))
    }

    /// Hot path: static inputs uploaded as literals once; potentials stay
    /// literals across iterations (no per-iteration host copies).
    fn solve_in_ctx_fast(&self, prob: &OtProblem, ctx: &BucketCtx) -> Result<(Potentials, SolveReport)> {
        let t0 = Instant::now();
        let schedule = self.cfg.schedule.resolve(prob.n, prob.m, prob.d);
        let k_fused = self.engine.manifest().k_fused;

        let x_lit = ctx.x.to_literal()?;
        let y_lit = ctx.y.to_literal()?;
        let a_lit = ctx.a.to_literal()?;
        let b_lit = ctx.b.to_literal()?;
        let mut f_lit =
            Tensor::vector(neg_padded(&ctx.alpha, ctx.bucket.n)).to_literal()?;
        let mut g_lit =
            Tensor::vector(neg_padded(&ctx.beta, ctx.bucket.m)).to_literal()?;

        let mut iters = 0usize;
        let mut delta = f32::INFINITY;
        let step_key = ctx.key(schedule.step_op());

        let run = |key: &str,
                       f_lit: &mut xla::Literal,
                       g_lit: &mut xla::Literal,
                       eps: f32|
         -> Result<f32> {
            let eps_lit = Tensor::scalar(eps).to_literal()?;
            let outs = self.engine.call_literals(
                key,
                &[&x_lit, &y_lit, f_lit, g_lit, &a_lit, &b_lit, &eps_lit],
            )?;
            let mut it = outs.into_iter();
            *f_lit = it.next().unwrap();
            *g_lit = it.next().unwrap();
            let df = it.next().unwrap().get_first_element::<f32>()?;
            let dg = it.next().unwrap().get_first_element::<f32>()?;
            Ok(df.max(dg))
        };

        if self.cfg.anneal_factor < 1.0 {
            let mut eps_level = prob.sq_diameter().max(prob.eps);
            while eps_level > prob.eps && iters < self.cfg.max_iters {
                run(&step_key, &mut f_lit, &mut g_lit, eps_level)?;
                eps_level *= self.cfg.anneal_factor;
                iters += 1;
            }
        }

        let fused_key = ctx.key(&schedule.fused_op(k_fused));
        let have_fused = self.cfg.use_fused && self.engine.manifest().has(&fused_key);
        while iters < self.cfg.max_iters && delta > self.cfg.tol {
            if have_fused && self.cfg.max_iters - iters >= k_fused {
                delta = run(&fused_key, &mut f_lit, &mut g_lit, prob.eps)?;
                iters += k_fused;
            } else {
                delta = run(&step_key, &mut f_lit, &mut g_lit, prob.eps)?;
                iters += 1;
            }
        }

        let fhat = f_lit.to_vec::<f32>()?;
        let ghat = g_lit.to_vec::<f32>()?;
        let pot = Potentials {
            fhat: fhat[..prob.n].to_vec(),
            ghat: ghat[..prob.m].to_vec(),
        };
        let cost = dual_cost(prob, &pot);
        Ok((
            pot,
            SolveReport {
                iters,
                final_delta: delta,
                cost,
                converged: delta <= self.cfg.tol,
                wall: t0.elapsed(),
                schedule,
                bucket: (ctx.bucket.n, ctx.bucket.m, ctx.bucket.d),
            },
        ))
    }

    fn step(
        &self,
        ctx: &BucketCtx,
        op: &str,
        fhat: &[f32],
        ghat: &[f32],
        eps: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32, f32)> {
        self.call_update(&ctx.key(op), ctx, fhat, ghat, eps)
    }

    fn call_update(
        &self,
        key: &str,
        ctx: &BucketCtx,
        fhat: &[f32],
        ghat: &[f32],
        eps: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32, f32)> {
        let outs = self.engine.call(
            key,
            &[
                ctx.x.clone(),
                ctx.y.clone(),
                Tensor::vector(fhat.to_vec()),
                Tensor::vector(ghat.to_vec()),
                ctx.a.clone(),
                ctx.b.clone(),
                Tensor::scalar(eps),
            ],
        )?;
        let f2 = outs[0].as_f32()?.to_vec();
        let g2 = outs[1].as_f32()?.to_vec();
        let df = outs[2].item()?;
        let dg = outs[3].item()?;
        Ok((f2, g2, df, dg))
    }
}

fn neg_padded(v: &[f32], len: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; len];
    for (o, &x) in out.iter_mut().zip(v) {
        *o = -x;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_parse_and_resolve() {
        assert_eq!(Schedule::parse("alternating"), Schedule::Alternating);
        assert_eq!(Schedule::parse("symmetric"), Schedule::Symmetric);
        assert_eq!(Schedule::parse("whatever"), Schedule::Auto);
        assert_eq!(Schedule::Auto.resolve(100, 100, 4), Schedule::Symmetric);
        assert_eq!(Schedule::Auto.resolve(40_000, 40_000, 128), Schedule::Alternating);
        assert_eq!(Schedule::Alternating.resolve(1, 1, 1), Schedule::Alternating);
    }

    #[test]
    fn neg_padded_layout() {
        assert_eq!(neg_padded(&[1.0, 2.0], 4), vec![-1.0, -2.0, 0.0, 0.0]);
    }

    #[test]
    fn fixed_iter_config() {
        let cfg = SolverConfig::fixed_iters(10, Schedule::Symmetric);
        assert_eq!(cfg.max_iters, 10);
        assert_eq!(cfg.tol, 0.0);
    }
}
