//! The Sinkhorn solver driver: the L3 iteration loop over backend ops.
//!
//! Rust owns everything the GPU library keeps in Python: schedule selection
//! (paper section H.2.4 crossover), epsilon annealing (section H.4),
//! convergence control, and the prepared-call hot path.  The loop is
//! backend-agnostic: the same driver runs on the native tiled-LSE backend
//! and (with `--features pjrt`) on precompiled HLO artifacts.

use std::time::Instant;

use anyhow::Result;

use crate::coordinator::router::{BucketCtx, Router};
use crate::runtime::{ComputeBackend, PreparedCall, Tensor};

use super::cost::dual_cost;
use super::problem::OtProblem;

/// Update schedule (paper eq. 2-3 vs eq. 4-5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// Gauss-Seidel (OTT-style): f from g, then g from the new f.
    Alternating,
    /// Jacobi half-step averaging (GeomLoss-style): both from old values.
    Symmetric,
    /// Paper Table 18 crossover: alternating for large n*d, symmetric below.
    Auto,
}

impl Schedule {
    pub fn parse(s: &str) -> Schedule {
        match s {
            "alternating" => Schedule::Alternating,
            "symmetric" => Schedule::Symmetric,
            _ => Schedule::Auto,
        }
    }

    /// Resolve Auto at a concrete problem size.  The paper's wall-clock
    /// crossover (Table 18) sits near n*d ~ 2*10^7 on A100; below it the
    /// fused symmetric kernel wins on launch overhead, above it the
    /// alternating half-steps win on throughput.
    pub fn resolve(self, n: usize, m: usize, d: usize) -> Schedule {
        match self {
            Schedule::Auto => {
                if n.max(m) * d >= (1 << 21) {
                    Schedule::Alternating
                } else {
                    Schedule::Symmetric
                }
            }
            s => s,
        }
    }

    fn step_op(self) -> &'static str {
        match self {
            Schedule::Alternating => "alternating_step",
            Schedule::Symmetric => "symmetric_step",
            Schedule::Auto => unreachable!("resolve() first"),
        }
    }

    fn fused_op(self, k: usize) -> String {
        match self {
            Schedule::Alternating => format!("k{k}_alternating"),
            Schedule::Symmetric => format!("k{k}_symmetric"),
            Schedule::Auto => unreachable!("resolve() first"),
        }
    }
}

/// Iteration-loop configuration for [`SinkhornSolver`].
#[derive(Debug, Clone)]
pub struct SolverConfig {
    /// Maximum Sinkhorn iterations (per eps level when annealing).
    pub max_iters: usize,
    /// Stop when the sup-norm potential change drops below this.
    pub tol: f32,
    /// Update schedule (alternating / symmetric / auto crossover).
    pub schedule: Schedule,
    /// Use the fused k-step op (one dispatch per k iterations) when far
    /// from tolerance.
    pub use_fused: bool,
    /// Epsilon annealing factor in (0, 1]; 1.0 disables (section H.4: 0.9).
    pub anneal_factor: f32,
    /// Hot-path optimization: freeze the static inputs (points, weights)
    /// in a [`PreparedCall`] once per solve so the iteration loop streams
    /// only the evolving potentials.  `false` selects the naive
    /// rebuild-every-iteration path (kept for before/after measurement).
    pub prepared: bool,
}

impl Default for SolverConfig {
    fn default() -> Self {
        Self {
            max_iters: 1000,
            tol: 1e-4,
            schedule: Schedule::Alternating,
            use_fused: true,
            anneal_factor: 1.0,
            prepared: true,
        }
    }
}

impl SolverConfig {
    /// Build from the launcher's JSON `solver` section.
    pub fn from_section(s: &crate::config::SolverSection) -> Self {
        Self {
            max_iters: s.max_iters,
            tol: s.tol,
            schedule: Schedule::parse(&s.schedule),
            use_fused: s.use_fused,
            anneal_factor: s.anneal_factor,
            prepared: true,
        }
    }

    /// A budget-pinned config: exactly `iters` iterations, no tolerance
    /// check (paper benchmarks fix 10).
    pub fn fixed_iters(iters: usize, schedule: Schedule) -> Self {
        Self { max_iters: iters, tol: 0.0, schedule, ..Self::default() }
    }
}

/// Shifted dual potentials (Prop. 1): fhat = f - |x|^2, ghat = g - |y|^2.
#[derive(Debug, Clone)]
pub struct Potentials {
    /// Shifted source potential, length n.
    pub fhat: Vec<f32>,
    /// Shifted target potential, length m.
    pub ghat: Vec<f32>,
}

/// What a solve did: iterations, convergence, cost, timing, routing.
#[derive(Debug, Clone)]
pub struct SolveReport {
    /// Sinkhorn iterations actually run.
    pub iters: usize,
    /// Last sup-norm potential change observed.
    pub final_delta: f32,
    /// The regularized OT cost `OT_eps` (dual objective).
    pub cost: f64,
    /// True when `final_delta` dropped below the tolerance in budget.
    pub converged: bool,
    /// Wall-clock time of the solve.
    pub wall: std::time::Duration,
    /// The schedule actually used (Auto resolved).
    pub schedule: Schedule,
    /// The (n, m, d) bucket the problem routed into.
    pub bucket: (usize, usize, usize),
}

/// The L3 iteration-loop driver: schedules backend step ops, controls
/// convergence and eps-annealing, and reports cost.  Backend-agnostic —
/// the same driver runs on the native tiled-LSE backend and on
/// precompiled HLO artifacts.
pub struct SinkhornSolver<'e> {
    backend: &'e dyn ComputeBackend,
    router: Router,
    /// The iteration-loop configuration this solver was built with.
    pub cfg: SolverConfig,
}

impl<'e> SinkhornSolver<'e> {
    /// A solver on `backend` with the given loop configuration.
    pub fn new(backend: &'e dyn ComputeBackend, cfg: SolverConfig) -> Self {
        let router = backend.router();
        Self { backend, router, cfg }
    }

    /// The backend's router (exact-fit on native, bucketed on PJRT).
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// The backend this solver dispatches to.
    pub fn backend(&self) -> &'e dyn ComputeBackend {
        self.backend
    }

    /// Solve: route to a bucket, pad if bucketed, iterate to tolerance or
    /// budget.  This is the top-level entry point for one EOT solve.
    ///
    /// # Example
    ///
    /// ```
    /// use flash_sinkhorn::prelude::*;
    ///
    /// let backend = NativeBackend::default();
    /// let (x, y) = (uniform_cloud(64, 4, 1), uniform_cloud(48, 4, 2));
    /// let prob = OtProblem::uniform(x, y, 64, 48, 4, 0.2).unwrap();
    /// let solver = SinkhornSolver::new(&backend, SolverConfig::default());
    /// let (potentials, report) = solver.solve(&prob).unwrap();
    /// assert!(report.converged);
    /// assert!(report.cost.is_finite());
    /// assert_eq!(potentials.fhat.len(), 64);
    /// assert_eq!(potentials.ghat.len(), 48);
    /// ```
    pub fn solve(&self, prob: &OtProblem) -> Result<(Potentials, SolveReport)> {
        let ctx = BucketCtx::new(&self.router, prob)?;
        self.solve_in_ctx(prob, &ctx)
    }

    /// Solve inside a pre-built context (reused by divergence / OTDD).
    pub fn solve_in_ctx(
        &self,
        prob: &OtProblem,
        ctx: &BucketCtx,
    ) -> Result<(Potentials, SolveReport)> {
        let t0 = Instant::now();
        let schedule = self.cfg.schedule.resolve(prob.n, prob.m, prob.d);
        let k_fused = self.backend.k_fused();

        // init = unshifted f = g = 0  =>  fhat = -alpha, ghat = -beta.
        let mut f = Tensor::vector(neg_padded(&ctx.alpha, ctx.bucket.n));
        let mut g = Tensor::vector(neg_padded(&ctx.beta, ctx.bucket.m));

        let step_key = ctx.key(schedule.step_op());
        let fused_key = ctx.key(&schedule.fused_op(k_fused));
        let have_fused = self.cfg.use_fused && self.backend.has(&fused_key);

        // one prepared call per op: statics (x, y, a, b) frozen, dynamics
        // (f, g, eps) streamed per iteration.
        let prep = |key: &str| {
            PreparedCall::new(
                self.backend,
                key,
                vec![
                    Some(ctx.x.clone()),
                    Some(ctx.y.clone()),
                    None, // fhat
                    None, // ghat
                    Some(ctx.a.clone()),
                    Some(ctx.b.clone()),
                    None, // eps
                ],
            )
        };
        let step_call = prep(&step_key);
        let fused_call = if have_fused { Some(prep(&fused_key)) } else { None };

        let run = |call: &PreparedCall<'_>, f: &mut Tensor, g: &mut Tensor, eps: f32| -> Result<f32> {
            let outs = if self.cfg.prepared {
                call.call(&[f.clone(), g.clone(), Tensor::scalar(eps)])?
            } else {
                // naive path: rebuild the full input list every iteration
                self.backend.call(
                    call.key(),
                    &[
                        ctx.x.clone(),
                        ctx.y.clone(),
                        f.clone(),
                        g.clone(),
                        ctx.a.clone(),
                        ctx.b.clone(),
                        Tensor::scalar(eps),
                    ],
                )?
            };
            let mut it = outs.into_iter();
            *f = it.next().ok_or_else(|| anyhow::anyhow!("step returned no f"))?;
            *g = it.next().ok_or_else(|| anyhow::anyhow!("step returned no g"))?;
            let df = it.next().ok_or_else(|| anyhow::anyhow!("step returned no df"))?.item()?;
            let dg = it.next().ok_or_else(|| anyhow::anyhow!("step returned no dg"))?.item()?;
            Ok(df.max(dg))
        };

        let mut iters = 0usize;
        let mut delta = f32::INFINITY;

        // epsilon annealing ladder (one iteration per level).
        if self.cfg.anneal_factor < 1.0 {
            let mut eps_level = prob.sq_diameter().max(prob.eps);
            while eps_level > prob.eps && iters < self.cfg.max_iters {
                run(&step_call, &mut f, &mut g, eps_level)?;
                eps_level *= self.cfg.anneal_factor;
                iters += 1;
            }
        }

        // main loop at target eps.
        while iters < self.cfg.max_iters && delta > self.cfg.tol {
            if let (Some(fused), true) =
                (&fused_call, self.cfg.max_iters - iters >= k_fused)
            {
                delta = run(fused, &mut f, &mut g, prob.eps)?;
                iters += k_fused;
            } else {
                delta = run(&step_call, &mut f, &mut g, prob.eps)?;
                iters += 1;
            }
        }

        let pot = Potentials {
            fhat: f.as_f32()?[..prob.n].to_vec(),
            ghat: g.as_f32()?[..prob.m].to_vec(),
        };
        let cost = dual_cost(prob, &pot);
        let report = SolveReport {
            iters,
            final_delta: delta,
            cost,
            converged: delta <= self.cfg.tol,
            wall: t0.elapsed(),
            schedule,
            bucket: (ctx.bucket.n, ctx.bucket.m, ctx.bucket.d),
        };
        Ok((pot, report))
    }
}

fn neg_padded(v: &[f32], len: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; len];
    for (o, &x) in out.iter_mut().zip(v) {
        *o = -x;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_parse_and_resolve() {
        assert_eq!(Schedule::parse("alternating"), Schedule::Alternating);
        assert_eq!(Schedule::parse("symmetric"), Schedule::Symmetric);
        assert_eq!(Schedule::parse("whatever"), Schedule::Auto);
        assert_eq!(Schedule::Auto.resolve(100, 100, 4), Schedule::Symmetric);
        assert_eq!(Schedule::Auto.resolve(40_000, 40_000, 128), Schedule::Alternating);
        assert_eq!(Schedule::Alternating.resolve(1, 1, 1), Schedule::Alternating);
    }

    #[test]
    fn neg_padded_layout() {
        assert_eq!(neg_padded(&[1.0, 2.0], 4), vec![-1.0, -2.0, 0.0, 0.0]);
    }

    #[test]
    fn fixed_iter_config() {
        let cfg = SolverConfig::fixed_iters(10, Schedule::Symmetric);
        assert_eq!(cfg.max_iters, 10);
        assert_eq!(cfg.tol, 0.0);
    }

    #[test]
    fn solves_on_native_backend_end_to_end() {
        let backend = crate::native::NativeBackend::default();
        let prob = OtProblem::uniform(
            crate::data::clouds::uniform_cloud(40, 3, 1),
            crate::data::clouds::uniform_cloud(50, 3, 2),
            40,
            50,
            3,
            0.2,
        )
        .unwrap();
        let solver = SinkhornSolver::new(&backend, SolverConfig::default());
        let (pot, report) = solver.solve(&prob).unwrap();
        assert!(report.converged, "delta {}", report.final_delta);
        assert_eq!(pot.fhat.len(), 40);
        assert_eq!(pot.ghat.len(), 50);
        assert_eq!(report.bucket, (40, 50, 3));
        assert!(report.cost.is_finite());
    }
}
