//! Debiased Sinkhorn divergence (Feydy et al. 2019; paper section 4.2):
//!
//! ```text
//! S_eps(mu, nu) = OT(mu, nu) - 1/2 OT(mu, mu) - 1/2 OT(nu, nu)
//! ```
//!
//! Three Sinkhorn solves per evaluation, exactly like the OTDD pipeline.

use anyhow::Result;

use crate::runtime::ComputeBackend;

use super::problem::OtProblem;
use super::solver::{SinkhornSolver, SolverConfig};
use super::Transport;

#[derive(Debug, Clone)]
pub struct DivergenceReport {
    pub value: f64,
    pub ot_xy: f64,
    pub ot_xx: f64,
    pub ot_yy: f64,
    pub total_iters: usize,
}

/// Debiased Sinkhorn divergence between (x, a) and (y, b).
pub fn sinkhorn_divergence(
    backend: &dyn ComputeBackend,
    cfg: &SolverConfig,
    x: &[f32],
    y: &[f32],
    a: &[f32],
    b: &[f32],
    n: usize,
    m: usize,
    d: usize,
    eps: f32,
) -> Result<DivergenceReport> {
    let solver = SinkhornSolver::new(backend, cfg.clone());
    let solve = |xs: &[f32], ys: &[f32], ws_a: &[f32], ws_b: &[f32], nn: usize, mm: usize| -> Result<(f64, usize)> {
        let prob = OtProblem::new(
            xs.to_vec(), ys.to_vec(), ws_a.to_vec(), ws_b.to_vec(), nn, mm, d, eps,
        )?;
        let (_, report) = solver.solve(&prob)?;
        Ok((report.cost, report.iters))
    };
    let (ot_xy, i1) = solve(x, y, a, b, n, m)?;
    let (ot_xx, i2) = solve(x, x, a, a, n, n)?;
    let (ot_yy, i3) = solve(y, y, b, b, m, m)?;
    Ok(DivergenceReport {
        value: ot_xy - 0.5 * ot_xx - 0.5 * ot_yy,
        ot_xy,
        ot_xx,
        ot_yy,
        total_iters: i1 + i2 + i3,
    })
}

/// Gradient of the debiased divergence w.r.t. X:
/// dS/dX = grad_1 OT(mu, nu) - grad_1 OT(mu, mu)
/// (the symmetric self-term contributes both slots; by symmetry that equals
/// one first-slot gradient -- see DESIGN.md / Feydy 2020).
pub fn divergence_grad(
    backend: &dyn ComputeBackend,
    cfg: &SolverConfig,
    x: &[f32],
    y: &[f32],
    a: &[f32],
    b: &[f32],
    n: usize,
    m: usize,
    d: usize,
    eps: f32,
) -> Result<Vec<f32>> {
    let solver = SinkhornSolver::new(backend, cfg.clone());

    let prob_xy = OtProblem::new(x.to_vec(), y.to_vec(), a.to_vec(), b.to_vec(), n, m, d, eps)?;
    let (pot_xy, _) = solver.solve(&prob_xy)?;
    let t_xy = Transport::new(backend, solver.router(), &prob_xy, &pot_xy)?;
    let (g_xy, _) = t_xy.grad_x()?;

    let prob_xx = OtProblem::new(x.to_vec(), x.to_vec(), a.to_vec(), a.to_vec(), n, n, d, eps)?;
    let (pot_xx, _) = solver.solve(&prob_xx)?;
    let t_xx = Transport::new(backend, solver.router(), &prob_xx, &pot_xx)?;
    let (g_xx, _) = t_xx.grad_x()?;

    Ok(g_xy.iter().zip(&g_xx).map(|(u, v)| u - v).collect())
}
