//! Dual initializers (Thornton & Cuturi, "Rethinking Initialization of the
//! Sinkhorn Algorithm"): closed-form warm starts for the dual potentials,
//! built from streaming per-marginal reductions -- O(n d + m d) time,
//! O(d) or O(d^2) memory, embarrassingly parallel, never a full cost
//! matrix.
//!
//! Both non-trivial initializers approximate the *unregularized* dual pair
//! of a simple surrogate transport and seed Sinkhorn with it; the
//! iteration then only has to correct the surrogate error plus the
//! entropic smoothing, instead of travelling from zero.
//!
//! Everything here returns **shifted** potentials (Prop. 1 convention:
//! `fhat = f - |x|^2`, `ghat = g - |y|^2`), matching what the backend step
//! ops consume.  Zero-weight rows get the zero-init value so warm starts
//! stay finite on empty support (the kernels mask those entries anyway).

use super::super::problem::{sqnorms, OtProblem};

/// Clamp for per-axis scale ratios: degenerate (near-constant) axes must
/// not blow the surrogate map up.
const SCALE_CLAMP: f32 = 1e4;

/// Variance floor (an axis can be exactly constant).
const VAR_FLOOR: f64 = 1e-12;

/// Power-iteration count for the principal-axis fallback of [`Initializer::Proj1d`].
const POWER_ITERS: usize = 32;

/// Where the dual iteration starts.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Initializer {
    /// Unshifted f = g = 0, i.e. `fhat = -|x|^2`, `ghat = -|y|^2` -- the
    /// legacy default.
    #[default]
    Zeros,
    /// Diagonal-Gaussian approximation: fit axis-aligned Gaussians to both
    /// marginals, use the closed-form Gaussian transport's dual pair.
    Gauss,
    /// 1-D projection: project both clouds on one direction, solve the
    /// projected transport exactly (north-west corner walk), lift the 1-D
    /// duals back.
    Proj1d,
}

impl Initializer {
    pub fn name(&self) -> &'static str {
        match self {
            Initializer::Zeros => "zeros",
            Initializer::Gauss => "gauss",
            Initializer::Proj1d => "1d",
        }
    }

    /// Shifted dual seeds `(fhat, ghat)` of real lengths (n, m).
    pub fn shifted_duals(&self, prob: &OtProblem) -> (Vec<f32>, Vec<f32>) {
        match self {
            Initializer::Zeros => zeros_init(prob),
            Initializer::Gauss => gauss_init(prob),
            Initializer::Proj1d => proj1d_init(prob),
        }
    }
}

/// `fhat = -alpha`, `ghat = -beta`: the zero unshifted duals.
fn zeros_init(prob: &OtProblem) -> (Vec<f32>, Vec<f32>) {
    let neg = |v: Vec<f32>| v.into_iter().map(|x| -x).collect();
    (neg(prob.alpha()), neg(prob.beta()))
}

/// Weighted per-axis mean and variance in one streaming pass pair.
/// Weights are assumed to sum to 1 (the [`OtProblem`] invariant).
fn moments(pts: &[f32], w: &[f32], n: usize, d: usize) -> (Vec<f64>, Vec<f64>) {
    let mut mean = vec![0.0f64; d];
    for i in 0..n {
        let wi = w[i] as f64;
        for (k, &v) in pts[i * d..(i + 1) * d].iter().enumerate() {
            mean[k] += wi * v as f64;
        }
    }
    let mut var = vec![0.0f64; d];
    for i in 0..n {
        let wi = w[i] as f64;
        for (k, &v) in pts[i * d..(i + 1) * d].iter().enumerate() {
            let c = v as f64 - mean[k];
            var[k] += wi * c * c;
        }
    }
    (mean, var)
}

/// Diagonal-Gaussian dual init.  Fit N(mx, diag(vx)) and N(my, diag(vy))
/// to the marginals; the optimal Gaussian-to-Gaussian map is the diagonal
/// affine `T(x)_k = s_k x_k + t_k` with `s_k = sqrt(vy_k / vx_k)`,
/// `t_k = my_k - s_k mx_k`.  Its Brenier potential (for cost
/// `1/2 |x - y|^2`) is `phi(x) = sum_k s_k x_k^2 / 2 + t_k x_k`, giving
/// for our cost `|x - y|^2` (twice the Brenier normalization) the shifted
/// dual pair
///
/// ```text
///   fhat_i = -2 phi(x_i)      = -sum_k (s_k x_ik^2 + 2 t_k x_ik)
///   ghat_j = -2 phi^*(y_j)    = -sum_k (y_jk - t_k)^2 / s_k
/// ```
fn gauss_init(prob: &OtProblem) -> (Vec<f32>, Vec<f32>) {
    let d = prob.d;
    let (mx, vx) = moments(&prob.x, &prob.a, prob.n, d);
    let (my, vy) = moments(&prob.y, &prob.b, prob.m, d);
    let mut s = vec![0.0f64; d];
    let mut t = vec![0.0f64; d];
    for k in 0..d {
        let ratio = (vy[k].max(VAR_FLOOR) / vx[k].max(VAR_FLOOR)).sqrt();
        s[k] = ratio.clamp(1.0 / SCALE_CLAMP as f64, SCALE_CLAMP as f64);
        t[k] = my[k] - s[k] * mx[k];
    }
    let fhat = (0..prob.n)
        .map(|i| {
            let row = &prob.x[i * d..(i + 1) * d];
            let phi2: f64 = row
                .iter()
                .enumerate()
                .map(|(k, &v)| {
                    let v = v as f64;
                    s[k] * v * v + 2.0 * t[k] * v
                })
                .sum();
            -phi2 as f32
        })
        .collect();
    let ghat = (0..prob.m)
        .map(|j| {
            let row = &prob.y[j * d..(j + 1) * d];
            let conj2: f64 = row
                .iter()
                .enumerate()
                .map(|(k, &v)| {
                    let c = v as f64 - t[k];
                    c * c / s[k]
                })
                .sum();
            -conj2 as f32
        })
        .collect();
    (fhat, ghat)
}

/// Direction for the 1-D projection: the (weighted) mean displacement, or
/// the principal axis of the pooled covariance when the means coincide.
fn projection_direction(prob: &OtProblem) -> Vec<f64> {
    let d = prob.d;
    let (mx, _) = moments(&prob.x, &prob.a, prob.n, d);
    let (my, _) = moments(&prob.y, &prob.b, prob.m, d);
    let mut u: Vec<f64> = (0..d).map(|k| my[k] - mx[k]).collect();
    let norm = u.iter().map(|v| v * v).sum::<f64>().sqrt();
    if norm > 1e-9 {
        for v in &mut u {
            *v /= norm;
        }
        return u;
    }
    // Means coincide: use the top eigenvector of the pooled (weighted)
    // covariance, found by power iteration from a deterministic start.
    let mut cov = vec![0.0f64; d * d];
    let mut accumulate = |pts: &[f32], w: &[f32], n: usize, mean: &[f64]| {
        for i in 0..n {
            let wi = w[i] as f64;
            let row = &pts[i * d..(i + 1) * d];
            for p in 0..d {
                let cp = row[p] as f64 - mean[p];
                for q in 0..d {
                    cov[p * d + q] += wi * cp * (row[q] as f64 - mean[q]);
                }
            }
        }
    };
    accumulate(&prob.x, &prob.a, prob.n, &mx);
    accumulate(&prob.y, &prob.b, prob.m, &my);
    let mut v: Vec<f64> = (0..d).map(|k| 1.0 / (k + 1) as f64).collect();
    for _ in 0..POWER_ITERS {
        let mut next = vec![0.0f64; d];
        for p in 0..d {
            next[p] = cov[p * d..(p + 1) * d].iter().zip(&v).map(|(&c, &x)| c * x).sum();
        }
        let norm = next.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm < 1e-30 {
            break; // degenerate cloud (all points equal): any direction works
        }
        for x in &mut next {
            *x /= norm;
        }
        v = next;
    }
    v
}

/// 1-D projection dual init.  Project both clouds on one direction, solve
/// the projected 1-D transport exactly via the monotone (north-west
/// corner) coupling, and read the duals off complementary slackness along
/// the walk: `f_i + g_j = (px_i - py_j)^2` on the support.  Lifting back,
/// the projected duals seed the full problem (`fhat_i = f1d_i - alpha_i`).
/// Zero-weight rows never enter the walk and keep the zero-init value.
fn proj1d_init(prob: &OtProblem) -> (Vec<f32>, Vec<f32>) {
    let d = prob.d;
    let u = projection_direction(prob);
    let project = |pts: &[f32], rows: usize| -> Vec<f64> {
        (0..rows)
            .map(|i| {
                pts[i * d..(i + 1) * d].iter().zip(&u).map(|(&p, &uk)| p as f64 * uk).sum()
            })
            .collect()
    };
    let px = project(&prob.x, prob.n);
    let py = project(&prob.y, prob.m);

    // active (positive-weight) indices sorted by projection, ties by index
    let sorted_active = |w: &[f32], proj: &[f64]| -> Vec<usize> {
        let mut idx: Vec<usize> = (0..w.len()).filter(|&i| w[i] > 0.0).collect();
        idx.sort_by(|&i, &j| proj[i].total_cmp(&proj[j]).then(i.cmp(&j)));
        idx
    };
    let xs = sorted_active(&prob.a, &px);
    let ys = sorted_active(&prob.b, &py);
    let (mut fhat, mut ghat) = zeros_init(prob);
    if xs.is_empty() || ys.is_empty() {
        return (fhat, ghat); // no support: keep the zero init
    }

    // North-west corner walk: advance whichever side exhausts its residual
    // mass, chaining duals through the monotone support (f64 throughout so
    // chain error does not accumulate over n).
    let cost = |i: usize, j: usize| {
        let dl = px[i] - py[j];
        dl * dl
    };
    let mut f1 = vec![0.0f64; prob.n];
    let mut g1 = vec![0.0f64; prob.m];
    let (mut i, mut j) = (0usize, 0usize);
    let mut wa = prob.a[xs[0]] as f64;
    let mut wb = prob.b[ys[0]] as f64;
    f1[xs[0]] = 0.0;
    g1[ys[0]] = cost(xs[0], ys[0]);
    while i + 1 < xs.len() || j + 1 < ys.len() {
        // on a tie the source advances first; the next round then advances
        // the target through a zero-mass boundary cell, which chains duals
        // consistently
        let advance_source = i + 1 < xs.len() && (j + 1 >= ys.len() || wa <= wb);
        if advance_source {
            wb -= wa;
            i += 1;
            wa = prob.a[xs[i]] as f64;
            f1[xs[i]] = cost(xs[i], ys[j]) - g1[ys[j]];
        } else {
            wa -= wb;
            j += 1;
            wb = prob.b[ys[j]] as f64;
            g1[ys[j]] = cost(xs[i], ys[j]) - f1[xs[i]];
        }
    }
    let alpha = sqnorms(&prob.x, prob.n, prob.d);
    let beta = sqnorms(&prob.y, prob.m, prob.d);
    for &i in &xs {
        fhat[i] = (f1[i] - alpha[i] as f64) as f32;
    }
    for &j in &ys {
        ghat[j] = (g1[j] - beta[j] as f64) as f32;
    }
    (fhat, ghat)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::clouds::uniform_cloud;

    fn affine_problem(n: usize, m: usize, d: usize, eps: f32) -> OtProblem {
        let x = uniform_cloud(n, d, 7);
        let mut y = uniform_cloud(m, d, 8);
        for (k, v) in y.iter_mut().enumerate() {
            *v = 0.5 * *v + 0.2 + 0.1 * (k % d) as f32;
        }
        OtProblem::uniform(x, y, n, m, d, eps).unwrap()
    }

    #[test]
    fn zeros_init_matches_neg_sqnorms() {
        let p = affine_problem(30, 40, 4, 0.1);
        let (f, g) = Initializer::Zeros.shifted_duals(&p);
        assert_eq!(f, p.alpha().iter().map(|v| -v).collect::<Vec<_>>());
        assert_eq!(g, p.beta().iter().map(|v| -v).collect::<Vec<_>>());
    }

    #[test]
    fn gauss_init_is_exact_for_matched_affine_points() {
        // y_j = S x_j + t with diagonal S on *identical* sample weights:
        // the surrogate map is exact, so the seeded duals must satisfy
        // fhat_i + ghat_j + 2 <x_i, y_j> = const on the matched pairs
        // (i = j), i.e. the matched-pair plan exponents are all equal.
        let (n, d) = (50, 3);
        let x = uniform_cloud(n, d, 3);
        let mut y = x.clone();
        for (k, v) in y.iter_mut().enumerate() {
            *v = [2.0, 0.5, 1.0][k % d] * *v + [0.3, -0.2, 0.0][k % d];
        }
        let p = OtProblem::uniform(x, y, n, n, d, 0.1).unwrap();
        let (f, g) = Initializer::Gauss.shifted_duals(&p);
        let exponent = |i: usize| {
            let dot: f32 = (0..d).map(|k| p.x[i * d + k] * p.y[i * d + k]).sum();
            f[i] + g[i] + 2.0 * dot
        };
        let e0 = exponent(0);
        for i in 1..n {
            assert!((exponent(i) - e0).abs() < 1e-3, "pair {i}: {} vs {e0}", exponent(i));
        }
    }

    #[test]
    fn initializers_are_finite_on_zero_weight_rows() {
        let (n, m, d) = (16, 18, 3);
        let x = uniform_cloud(n, d, 1);
        let y = uniform_cloud(m, d, 2);
        let mut a = vec![1.0 / (n - 2) as f32; n];
        a[0] = 0.0;
        a[5] = 0.0;
        let mut b = vec![1.0 / (m - 1) as f32; m];
        b[17] = 0.0;
        let p = OtProblem::new(x, y, a, b, n, m, d, 0.1).unwrap();
        for init in [Initializer::Zeros, Initializer::Gauss, Initializer::Proj1d] {
            let (f, g) = init.shifted_duals(&p);
            assert_eq!(f.len(), n);
            assert_eq!(g.len(), m);
            assert!(f.iter().all(|v| v.is_finite()), "{:?}: {f:?}", init);
            assert!(g.iter().all(|v| v.is_finite()), "{:?}: {g:?}", init);
        }
    }

    #[test]
    fn proj1d_duals_satisfy_slackness_on_sorted_support() {
        // uniform weights, distinct projections: the monotone coupling is
        // the sorted pairing, so f1d_i + g1d_j = c(i, j) must hold for the
        // diagonal pairs after sorting both sides.
        let n = 8;
        let x: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let y: Vec<f32> = (0..n).map(|i| 0.5 * i as f32 + 3.0).collect();
        let p = OtProblem::uniform(x, y, n, n, 1, 0.1).unwrap();
        let (fhat, ghat) = Initializer::Proj1d.shifted_duals(&p);
        // undo the shift to recover the raw projected duals
        let alpha = p.alpha();
        let beta = p.beta();
        for i in 0..n {
            let f1 = fhat[i] + alpha[i];
            let g1 = ghat[i] + beta[i];
            let c = (p.x[i] - p.y[i]) * (p.x[i] - p.y[i]);
            assert!((f1 + g1 - c).abs() < 1e-4, "pair {i}: {f1} + {g1} != {c}");
        }
    }

    #[test]
    fn projection_direction_falls_back_to_principal_axis() {
        // identical means, variance concentrated on axis 0
        let n = 40;
        let mut x = vec![0.0f32; n * 2];
        let mut y = vec![0.0f32; n * 2];
        for i in 0..n {
            let t = (i as f32 / n as f32) - 0.5;
            x[i * 2] = 2.0 * t;
            y[i * 2] = -2.0 * t; // same axis, same mean, mirrored
            x[i * 2 + 1] = 0.01 * t;
            y[i * 2 + 1] = 0.01 * t;
        }
        let p = OtProblem::uniform(x, y, n, n, 2, 0.1).unwrap();
        let u = projection_direction(&p);
        assert!(u[0].abs() > 0.99, "principal axis should dominate: {u:?}");
    }
}
