//! Epsilon-annealing schedules: a geometric ladder of regularization
//! strengths, duals carried across stages.
//!
//! Unlike the per-iteration H.4 ladder baked into the legacy loop (one
//! iteration per level, `anneal_factor`), a staged schedule runs each
//! intermediate level to a loose tolerance before shrinking eps, which is
//! what actually transfers a warm dual: at each level the iterate lands in
//! the contraction basin of the next, so the expensive low-eps stage starts
//! close to its fixed point.

/// Default number of ladder stages for `anneal` with no explicit count.
pub const DEFAULT_STAGES: usize = 4;

/// Intermediate stages stop at this multiple of the final tolerance:
/// warm-up levels only need to reach the next level's basin, not converge.
pub const STAGE_TOL_FACTOR: f32 = 10.0;

/// Tolerance for a non-final annealing stage.
pub fn stage_tol(final_tol: f32) -> f32 {
    final_tol * STAGE_TOL_FACTOR
}

/// A geometric epsilon ladder with a fixed number of stages; the last
/// stage is always exactly the target eps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AnnealSchedule {
    /// Total number of stages (>= 1); 1 degenerates to the plain solver.
    pub stages: usize,
}

impl AnnealSchedule {
    pub fn new(stages: usize) -> Self {
        Self { stages: stages.max(1) }
    }

    /// The eps values of each stage, strictly decreasing from `eps_start`
    /// down to exactly `eps_target`.  Degenerates to `[eps_target]` when
    /// one stage is requested or the start is not above the target.
    pub fn stages_for(&self, eps_start: f32, eps_target: f32) -> Vec<f32> {
        if self.stages <= 1 || eps_start <= eps_target {
            return vec![eps_target];
        }
        let k = self.stages;
        // eps_i = eps_start * rho^i with rho solved so eps_{k-1} = target
        let rho = (eps_target as f64 / eps_start as f64).powf(1.0 / (k - 1) as f64);
        let mut out: Vec<f32> = (0..k)
            .map(|i| (eps_start as f64 * rho.powi(i as i32)) as f32)
            .collect();
        out[k - 1] = eps_target; // exact target, no float drift
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_is_geometric_and_lands_on_target() {
        let s = AnnealSchedule::new(4).stages_for(8.0, 0.1);
        assert_eq!(s.len(), 4);
        assert_eq!(s[0], 8.0);
        assert_eq!(s[3], 0.1);
        assert!(s.windows(2).all(|w| w[0] > w[1]), "{s:?}");
        // geometric: roughly constant ratio between consecutive levels
        let r0 = s[1] / s[0];
        let r1 = s[2] / s[1];
        assert!((r0 - r1).abs() < 1e-3, "{s:?}");
    }

    #[test]
    fn degenerate_ladders_collapse_to_target() {
        assert_eq!(AnnealSchedule::new(1).stages_for(8.0, 0.1), vec![0.1]);
        assert_eq!(AnnealSchedule::new(0).stages, 1);
        // start at or below target: nothing to anneal
        assert_eq!(AnnealSchedule::new(5).stages_for(0.1, 0.1), vec![0.1]);
        assert_eq!(AnnealSchedule::new(5).stages_for(0.05, 0.1), vec![0.1]);
    }

    #[test]
    fn stage_tol_loosens_intermediate_stages() {
        assert_eq!(stage_tol(1e-4), 1e-3);
    }
}
