//! Truncated-Newton switch-over (Kemertas et al., "A Truncated Newton
//! Method for Optimal Transport"): once Sinkhorn gets close, solve the
//! dual first-order conditions with Newton steps instead of fixed-point
//! iterations.
//!
//! The dual residual is `F(fhat, ghat) = (r - a, c - b)` with the induced
//! marginals `r = P 1`, `c = P^T 1`.  Its Jacobian is
//! `(1/eps) [diag(r), P; P^T, diag(c)]`, so the Newton system reads
//!
//! ```text
//!   [diag(r)  P      ] [df]       [a - r]
//!   [P^T      diag(c)] [dg] = eps [b - c]
//! ```
//!
//! Eliminating `df` leaves the Schur system
//! `(diag(c) + tau - P^T diag(r)^-1 P) dg = eps (b - c) - P^T u` with
//! `u_i = eps (a_i - r_i) / r_i` -- exactly the damped operator the HVP
//! path already exposes as [`crate::ot::apply::SchurOp`], solved matrix-free
//! by [`crate::hvp::cg::cg_solve`].  Each outer step costs one CG solve
//! (2 transport applications per CG iteration, Thm. 5) plus a short
//! backtracking line search on the L1 marginal error.
//!
//! The polish **falls back cleanly**: if CG stalls or no damped step
//! reduces the marginal error, it returns with `fell_back = true` and
//! untouched-or-improved duals, and the driver resumes plain Sinkhorn.

use anyhow::Result;

use crate::coordinator::router::BucketCtx;
use crate::hvp::cg::cg_solve;
use crate::ot::apply::Transport;
use crate::ot::solver::Potentials;
use crate::runtime::ComputeBackend;

/// Default Sinkhorn sup-norm delta at which the driver hands off.
pub const DEFAULT_SWITCH_AT: f32 = 1e-2;

/// When to switch from Sinkhorn to Newton, and how hard to push.
#[derive(Debug, Clone, PartialEq)]
pub struct NewtonPolicy {
    /// Hand off once the Sinkhorn sup-norm potential delta drops here.
    pub switch_at: f32,
    /// Tikhonov damping for the Schur system (paper default 1e-5).
    pub tau: f32,
    /// CG relative-residual tolerance.
    pub eta: f64,
    /// CG iteration cap per Newton step; 0 forces immediate fallback
    /// (used by the fallback tests).
    pub max_cg: usize,
    /// Outer Newton step cap.
    pub max_steps: usize,
    /// Stop when the L1 marginal error `|r - a|_1 + |c - b|_1` drops here.
    pub marginal_tol: f32,
}

impl Default for NewtonPolicy {
    fn default() -> Self {
        Self {
            switch_at: DEFAULT_SWITCH_AT,
            tau: 1e-5,
            eta: 1e-6,
            max_cg: 50,
            max_steps: 10,
            marginal_tol: 1e-4,
        }
    }
}

impl NewtonPolicy {
    pub fn with_switch_at(switch_at: f32) -> Self {
        Self { switch_at, ..Self::default() }
    }
}

/// What the Newton polish did.
#[derive(Debug, Clone)]
pub struct NewtonOutcome {
    /// Accepted Newton steps.
    pub steps: usize,
    /// Total CG iterations across all steps.
    pub cg_iters: usize,
    /// True when the marginal error reached `marginal_tol`.
    pub converged: bool,
    /// True when the polish stopped because CG stalled or the line search
    /// found no descent (the driver then resumes Sinkhorn).
    pub fell_back: bool,
    /// L1 marginal error at exit.
    pub final_marginal_err: f32,
}

fn l1_marginal_err(r: &[f32], c: &[f32], a: &[f32], b: &[f32]) -> f32 {
    let sum = |u: &[f32], v: &[f32]| -> f64 {
        u.iter().zip(v).map(|(&x, &y)| (x as f64 - y as f64).abs()).sum()
    };
    (sum(r, a) + sum(c, b)) as f32
}

/// Backtracking step sizes tried per Newton direction.
const STEPS: [f32; 3] = [1.0, 0.5, 0.25];

/// Newton-polish `pot` in place.  `ctx` is the routed bucket of the
/// problem the duals belong to; every transport application reuses it.
pub fn polish(
    backend: &dyn ComputeBackend,
    ctx: &BucketCtx,
    pot: &mut Potentials,
    policy: &NewtonPolicy,
) -> Result<NewtonOutcome> {
    let eps = ctx.eps;
    let a = ctx.a.as_f32()?[..ctx.n].to_vec();
    let b = ctx.b.as_f32()?[..ctx.m].to_vec();
    let mut out = NewtonOutcome {
        steps: 0,
        cg_iters: 0,
        converged: false,
        fell_back: false,
        final_marginal_err: f32::INFINITY,
    };
    let (mut r, mut c) = Transport::with_ctx(backend, ctx.clone(), pot).marginals()?;
    let mut err = l1_marginal_err(&r, &c, &a, &b);
    while out.steps < policy.max_steps && err > policy.marginal_tol {
        let t = Transport::with_ctx(backend, ctx.clone(), pot);
        // rhs of the Schur system: eps (b - c) - P^T u,  u_i = eps (a_i - r_i) / r_i
        let u: Vec<f32> =
            a.iter().zip(&r).map(|(&ai, &ri)| if ri > 0.0 { eps * (ai - ri) / ri } else { 0.0 }).collect();
        let (ptu, _) = t.apply_ptu(&u, 1)?;
        let rhs: Vec<f32> =
            b.iter().zip(&c).zip(&ptu).map(|((&bj, &cj), &p)| eps * (bj - cj) - p).collect();
        let schur = t.schur_op(&r, &c, policy.tau)?;
        let cg = cg_solve(|w| schur.matvec(w), &rhs, policy.eta, policy.max_cg)?;
        out.cg_iters += cg.iters;
        if !cg.converged {
            out.fell_back = true;
            break;
        }
        let dg = cg.x;
        // back-substitute: df_i = (eps (a_i - r_i) - (P dg)_i) / r_i
        let (pdg, _) = t.apply_pv(&dg, 1)?;
        let df: Vec<f32> = a
            .iter()
            .zip(&r)
            .zip(&pdg)
            .map(|((&ai, &ri), &p)| if ri > 0.0 { (eps * (ai - ri) - p) / ri } else { 0.0 })
            .collect();
        // backtracking line search on the L1 marginal error
        let mut accepted = false;
        for &s in &STEPS {
            let trial = Potentials {
                fhat: pot.fhat.iter().zip(&df).map(|(&f, &d)| f + s * d).collect(),
                ghat: pot.ghat.iter().zip(&dg).map(|(&g, &d)| g + s * d).collect(),
            };
            let (rt, ct) = Transport::with_ctx(backend, ctx.clone(), &trial).marginals()?;
            let errt = l1_marginal_err(&rt, &ct, &a, &b);
            if errt.is_finite() && errt < err {
                *pot = trial;
                r = rt;
                c = ct;
                err = errt;
                accepted = true;
                break;
            }
        }
        if !accepted {
            out.fell_back = true;
            break;
        }
        out.steps += 1;
    }
    out.final_marginal_err = err;
    out.converged = err <= policy.marginal_tol;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::BucketCtx;
    use crate::data::clouds::uniform_cloud;
    use crate::native::NativeBackend;
    use crate::ot::problem::OtProblem;
    use crate::ot::solver::{SinkhornSolver, SolverConfig};
    use crate::runtime::ComputeBackend as _;

    fn warm_duals(backend: &NativeBackend, prob: &OtProblem, iters: usize) -> Potentials {
        let cfg = SolverConfig { max_iters: iters, tol: 0.0, ..SolverConfig::default() };
        SinkhornSolver::new(backend, cfg).solve(prob).unwrap().0
    }

    #[test]
    fn polish_reduces_marginal_error() {
        let backend = NativeBackend::default();
        let (n, m, d) = (60, 70, 4);
        let prob = OtProblem::uniform(
            uniform_cloud(n, d, 1),
            uniform_cloud(m, d, 2),
            n,
            m,
            d,
            0.1,
        )
        .unwrap();
        let mut pot = warm_duals(&backend, &prob, 30);
        let ctx = BucketCtx::new(&backend.router(), &prob).unwrap();
        let before = {
            let (r, c) = Transport::with_ctx(&backend, ctx.clone(), &pot).marginals().unwrap();
            let a = ctx.a.as_f32().unwrap()[..n].to_vec();
            let b = ctx.b.as_f32().unwrap()[..m].to_vec();
            l1_marginal_err(&r, &c, &a, &b)
        };
        let out = polish(&backend, &ctx, &mut pot, &NewtonPolicy::default()).unwrap();
        assert!(!out.fell_back, "unexpected fallback: {out:?}");
        assert!(out.final_marginal_err <= before, "{} > {before}", out.final_marginal_err);
        assert!(out.converged, "err {}", out.final_marginal_err);
    }

    #[test]
    fn zero_cg_budget_falls_back_immediately() {
        let backend = NativeBackend::default();
        let (n, d) = (30, 3);
        let prob =
            OtProblem::uniform(uniform_cloud(n, d, 3), uniform_cloud(n, d, 4), n, n, d, 0.1)
                .unwrap();
        let mut pot = warm_duals(&backend, &prob, 10);
        let ctx = BucketCtx::new(&backend.router(), &prob).unwrap();
        // marginal_tol 0 guarantees the loop is entered; max_cg 0 then
        // makes the very first Schur solve report non-convergence
        let policy = NewtonPolicy { max_cg: 0, marginal_tol: 0.0, ..NewtonPolicy::default() };
        let out = polish(&backend, &ctx, &mut pot, &policy).unwrap();
        assert!(out.fell_back);
        assert_eq!(out.steps, 0);
        assert!(!out.converged);
    }
}
