//! Entropic optimal transport: problems, the Sinkhorn solver driver, and
//! streaming transport application -- the Rust face of the paper's core
//! algorithm (sections 2-3).

pub mod apply;
pub mod cost;
pub mod divergence;
pub mod problem;
pub mod solver;
pub mod strategy;

pub use apply::Transport;
pub use problem::OtProblem;
pub use solver::{Potentials, Schedule, SinkhornSolver, SolveReport, SolverConfig, StageTrace};
pub use strategy::SolveStrategy;
