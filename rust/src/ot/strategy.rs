//! Composable solve strategies: the policy layer above the Sinkhorn loop.
//!
//! A [`SolveStrategy`] bundles three orthogonal convergence levers, all of
//! them backend-agnostic (they drive [`crate::runtime::ComputeBackend`] ops
//! and never touch kernel internals):
//!
//! * **Dual initialization** ([`init::Initializer`]): where the iteration
//!   starts.  Besides the default zeros, the Thornton-Cuturi Gaussian
//!   approximation and 1-D projection initializers build warm duals from
//!   streaming per-marginal reductions (linear memory, one pass over the
//!   points).
//! * **Epsilon annealing** ([`anneal::AnnealSchedule`]): a geometric ladder
//!   of intermediate regularization strengths from a diameter-scaled start
//!   down to the target, duals carried across stages (safe since PR 2's
//!   explicit zero-weight masking ignores stale duals on empty support).
//! * **Newton switch-over** ([`newton::NewtonPolicy`]): once the Sinkhorn
//!   phase reaches a coarse threshold, hand off to a truncated-Newton
//!   polish on the dual system, reusing the existing Schur/CG machinery
//!   ([`crate::ot::apply::SchurOp`], [`crate::hvp::cg`]).  Falls back to
//!   plain Sinkhorn iterations when the inner solve does not converge.
//!
//! Strategies parse from a compact `+`-separated spec (config key
//! `solver.strategy`, env `FLASH_SINKHORN_STRATEGY`, CLI `--strategy`):
//!
//! ```text
//! plain                 the legacy solver, bit-for-bit
//! gauss                 Gaussian-approximation dual init
//! 1d                    1-D projection dual init
//! gauss+anneal:4        Gaussian init + 4-stage epsilon ladder
//! zeros+anneal          zero init + default ladder (4 stages)
//! gauss+newton:1e-2     Gaussian init + Newton hand-off at delta 1e-2
//! gauss+anneal+newton   all three composed
//! ```
//!
//! The `plain` strategy is the identity policy: the driver runs the exact
//! legacy code path, so results are bitwise identical to the pre-strategy
//! solver.  `anneal:1` degenerates to the same single-stage loop and is
//! likewise bitwise `plain` (covered by tests).

pub mod anneal;
pub mod init;
pub mod newton;

use anyhow::{bail, Result};

pub use anneal::AnnealSchedule;
pub use init::Initializer;
pub use newton::NewtonPolicy;

use super::problem::OtProblem;

/// A composed solve policy: initialization + annealing + Newton hand-off.
#[derive(Debug, Clone, PartialEq)]
pub struct SolveStrategy {
    /// Where the dual iteration starts.
    pub init: Initializer,
    /// Optional epsilon ladder run before the target-eps stage.
    pub anneal: Option<AnnealSchedule>,
    /// Optional truncated-Newton polish after the Sinkhorn phase.
    pub newton: Option<NewtonPolicy>,
}

impl Default for SolveStrategy {
    fn default() -> Self {
        Self::plain()
    }
}

impl SolveStrategy {
    /// The identity policy: zero init, no annealing, no Newton -- the
    /// legacy solver, bit-for-bit.
    pub fn plain() -> Self {
        Self { init: Initializer::Zeros, anneal: None, newton: None }
    }

    /// True when this strategy changes nothing about the legacy loop.
    pub fn is_plain(&self) -> bool {
        self.init == Initializer::Zeros && self.anneal.is_none() && self.newton.is_none()
    }

    /// Parse a `+`-separated spec; see the module docs for the grammar.
    pub fn parse(spec: &str) -> Result<Self> {
        let spec = spec.trim().to_ascii_lowercase();
        if spec.is_empty() || spec == "plain" {
            return Ok(Self::plain());
        }
        let mut out = Self::plain();
        let mut init_seen = false;
        for token in spec.split('+').map(str::trim) {
            let (head, arg) = match token.split_once(':') {
                Some((h, a)) => (h, Some(a)),
                None => (token, None),
            };
            let mut set_init = |i: Initializer| -> Result<()> {
                if init_seen {
                    bail!("strategy '{spec}': more than one initializer");
                }
                init_seen = true;
                out.init = i;
                Ok(())
            };
            match head {
                "zeros" => set_init(Initializer::Zeros)?,
                "gauss" | "gaussian" => set_init(Initializer::Gauss)?,
                "1d" | "proj1d" => set_init(Initializer::Proj1d)?,
                "anneal" => {
                    if out.anneal.is_some() {
                        bail!("strategy '{spec}': 'anneal' given twice");
                    }
                    let stages = match arg {
                        None => anneal::DEFAULT_STAGES,
                        Some(a) => a
                            .parse::<usize>()
                            .ok()
                            .filter(|&k| k >= 1)
                            .ok_or_else(|| {
                                anyhow::anyhow!("strategy '{spec}': anneal stage count '{a}' must be an integer >= 1")
                            })?,
                    };
                    out.anneal = Some(AnnealSchedule::new(stages));
                }
                "newton" => {
                    if out.newton.is_some() {
                        bail!("strategy '{spec}': 'newton' given twice");
                    }
                    let switch_at = match arg {
                        None => newton::DEFAULT_SWITCH_AT,
                        Some(a) => a
                            .parse::<f32>()
                            .ok()
                            .filter(|t| t.is_finite() && *t > 0.0)
                            .ok_or_else(|| {
                                anyhow::anyhow!("strategy '{spec}': newton threshold '{a}' must be a positive float")
                            })?,
                    };
                    out.newton = Some(NewtonPolicy::with_switch_at(switch_at));
                }
                "plain" => {
                    bail!("strategy '{spec}': 'plain' cannot be combined with other tokens")
                }
                other => bail!(
                    "unknown strategy token '{other}' in '{spec}' \
                     (grammar: plain | zeros | gauss | 1d [+anneal[:K]] [+newton[:T]])"
                ),
            }
        }
        Ok(out)
    }

    /// The epsilon ladder this strategy solves through; always ends at
    /// `prob.eps`.  `[prob.eps]` when annealing is off (or degenerate).
    pub fn eps_stages(&self, prob: &OtProblem) -> Vec<f32> {
        match &self.anneal {
            Some(a) => a.stages_for(prob.sq_diameter().max(prob.eps), prob.eps),
            None => vec![prob.eps],
        }
    }
}

impl std::fmt::Display for SolveStrategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.is_plain() {
            return write!(f, "plain");
        }
        write!(f, "{}", self.init.name())?;
        if let Some(a) = &self.anneal {
            write!(f, "+anneal:{}", a.stages)?;
        }
        if let Some(n) = &self.newton {
            write!(f, "+newton:{}", n.switch_at)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_plain_and_empty() {
        assert!(SolveStrategy::parse("plain").unwrap().is_plain());
        assert!(SolveStrategy::parse("").unwrap().is_plain());
        assert!(SolveStrategy::parse("  PLAIN ").unwrap().is_plain());
        assert!(SolveStrategy::parse("zeros").unwrap().is_plain());
    }

    #[test]
    fn parses_composed_specs() {
        let s = SolveStrategy::parse("gauss+anneal:3+newton:0.05").unwrap();
        assert_eq!(s.init, Initializer::Gauss);
        assert_eq!(s.anneal.as_ref().unwrap().stages, 3);
        assert!((s.newton.as_ref().unwrap().switch_at - 0.05).abs() < 1e-9);

        let s = SolveStrategy::parse("1d+anneal").unwrap();
        assert_eq!(s.init, Initializer::Proj1d);
        assert_eq!(s.anneal.as_ref().unwrap().stages, anneal::DEFAULT_STAGES);
        assert!(s.newton.is_none());

        let s = SolveStrategy::parse("newton").unwrap();
        assert_eq!(s.init, Initializer::Zeros);
        assert_eq!(s.newton.as_ref().unwrap().switch_at, newton::DEFAULT_SWITCH_AT);
    }

    #[test]
    fn display_roundtrips_through_parse() {
        for spec in ["plain", "gauss", "1d+anneal:4", "gauss+anneal:2+newton:0.01"] {
            let s = SolveStrategy::parse(spec).unwrap();
            assert_eq!(SolveStrategy::parse(&s.to_string()).unwrap(), s, "spec {spec}");
        }
    }

    #[test]
    fn rejects_bad_specs() {
        for bad in [
            "gauss+1d",          // two initializers
            "plain+anneal",      // plain does not compose
            "anneal:0",          // stages must be >= 1
            "anneal+anneal",     // duplicate
            "newton:-1",         // threshold must be positive
            "newton:zzz",        // not a float
            "warp",              // unknown token
        ] {
            assert!(SolveStrategy::parse(bad).is_err(), "should reject '{bad}'");
        }
    }

    #[test]
    fn eps_stages_end_at_target() {
        let prob = OtProblem::uniform(
            crate::data::clouds::uniform_cloud(20, 3, 1),
            crate::data::clouds::uniform_cloud(25, 3, 2),
            20,
            25,
            3,
            0.05,
        )
        .unwrap();
        let plain = SolveStrategy::plain();
        assert_eq!(plain.eps_stages(&prob), vec![0.05]);
        let ann = SolveStrategy::parse("anneal:4").unwrap();
        let stages = ann.eps_stages(&prob);
        assert_eq!(stages.len(), 4);
        assert_eq!(*stages.last().unwrap(), 0.05);
        assert!(stages.windows(2).all(|w| w[0] > w[1]), "{stages:?}");
    }
}
