//! Streaming transport application (paper section 3.2, Algorithms 2/4/5):
//! PV, P^T U, Hadamard-weighted transport, gradients, marginals and the
//! Schur-complement matvec -- all matrix-free, routed through the fused
//! streaming backend ops.

use anyhow::Result;

use crate::coordinator::router::{BucketCtx, Router};
use crate::runtime::{ComputeBackend, PreparedCall, Tensor};

use super::problem::OtProblem;
use super::solver::Potentials;

/// A transport operator bound to (problem, potentials): the Rust-side
/// object implementing `P * ()`, `P^T * ()`, `(P . W) * ()` and eq. (17).
/// Potentials may be *any* values (Prop. 3 holds pre-convergence); the
/// induced marginals r, c come back with every application.
pub struct Transport<'e> {
    backend: &'e dyn ComputeBackend,
    pub ctx: BucketCtx,
    fhat_p: Tensor,
    ghat_p: Tensor,
    eps: Tensor,
}

impl<'e> Transport<'e> {
    pub fn new(
        backend: &'e dyn ComputeBackend,
        router: &Router,
        prob: &OtProblem,
        pot: &Potentials,
    ) -> Result<Self> {
        let ctx = BucketCtx::new(router, prob)?;
        Ok(Self::with_ctx(backend, ctx, pot))
    }

    pub fn with_ctx(backend: &'e dyn ComputeBackend, ctx: BucketCtx, pot: &Potentials) -> Self {
        let fhat_p = ctx.pad_n(&pot.fhat, 0.0);
        let ghat_p = ctx.pad_m(&pot.ghat, 0.0);
        let eps = Tensor::scalar(ctx.eps);
        Self { backend, ctx, fhat_p, ghat_p, eps }
    }

    fn base_inputs(&self) -> Vec<Tensor> {
        vec![
            self.ctx.x.clone(),
            self.ctx.y.clone(),
            self.fhat_p.clone(),
            self.ghat_p.clone(),
            self.ctx.a.clone(),
            self.ctx.b.clone(),
        ]
    }

    /// PV for V of shape (m, p) with p in {1, d}.  Returns (PV, r = P 1_m).
    pub fn apply_pv(&self, v: &[f32], p: usize) -> Result<(Vec<f32>, Vec<f32>)> {
        let op = if p == 1 { "apply_pv_p1" } else { "apply_pv_pd" };
        let mut inputs = self.base_inputs();
        inputs.push(self.ctx.pad_m_mat(v, p));
        inputs.push(self.eps.clone());
        let outs = self.backend.call(&self.ctx.key(op), &inputs)?;
        Ok((self.ctx.slice_n_mat(&outs[0], p)?, self.ctx.slice_n(&outs[1])?))
    }

    /// P^T U for U of shape (n, p).  Returns (P^T U, c = P^T 1_n).
    pub fn apply_ptu(&self, u: &[f32], p: usize) -> Result<(Vec<f32>, Vec<f32>)> {
        let op = if p == 1 { "apply_ptu_p1" } else { "apply_ptu_pd" };
        let mut inputs = self.base_inputs();
        inputs.push(self.ctx.pad_n_mat(u, p));
        inputs.push(self.eps.clone());
        let outs = self.backend.call(&self.ctx.key(op), &inputs)?;
        Ok((self.ctx.slice_m_mat(&outs[0], p)?, self.ctx.slice_m(&outs[1])?))
    }

    /// (P . (A B^T)) V with A (n, d), B (m, d), V (m, d)  (Algorithm 5).
    pub fn hadamard_pv(&self, aa: &[f32], bb: &[f32], v: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let d = self.ctx.d;
        let mut inputs = self.base_inputs();
        inputs.push(self.ctx.pad_n_mat(aa, d));
        inputs.push(self.ctx.pad_m_mat(bb, d));
        inputs.push(self.ctx.pad_m_mat(v, d));
        inputs.push(self.eps.clone());
        let outs = self.backend.call(&self.ctx.key("hadamard_pv"), &inputs)?;
        Ok((self.ctx.slice_n_mat(&outs[0], d)?, self.ctx.slice_n(&outs[1])?))
    }

    /// Gradient of OT_eps w.r.t. X (eq. 17, induced marginals): (grad, r).
    pub fn grad_x(&self) -> Result<(Vec<f32>, Vec<f32>)> {
        let mut inputs = self.base_inputs();
        inputs.push(self.eps.clone());
        let outs = self.backend.call(&self.ctx.key("grad_x"), &inputs)?;
        Ok((self.ctx.slice_n_mat(&outs[0], self.ctx.d)?, self.ctx.slice_n(&outs[1])?))
    }

    /// Induced marginals (r, c) = (P 1, P^T 1) (eq. 13-14).
    pub fn marginals(&self) -> Result<(Vec<f32>, Vec<f32>)> {
        let mut inputs = self.base_inputs();
        inputs.push(self.eps.clone());
        let outs = self.backend.call(&self.ctx.key("marginals"), &inputs)?;
        Ok((self.ctx.slice_n(&outs[0])?, self.ctx.slice_m(&outs[1])?))
    }

    /// Damped Schur matvec: (diag(bhat) + tau) w - P^T diag(ahat)^-1 P w
    /// (Thm. 5 / eq. 30).  One call = one CG iteration's transport work.
    pub fn schur_matvec(&self, ahat: &[f32], bhat: &[f32], w2: &[f32], tau: f32) -> Result<Vec<f32>> {
        let mut inputs = self.base_inputs();
        inputs.push(self.ctx.pad_n(ahat, 0.0));
        inputs.push(self.ctx.pad_m(bhat, 0.0));
        inputs.push(self.ctx.pad_m(w2, 0.0));
        inputs.push(Tensor::scalar(tau));
        inputs.push(self.eps.clone());
        let outs = self.backend.call(&self.ctx.key("schur_matvec"), &inputs)?;
        self.ctx.slice_m(&outs[0])
    }

    /// Barycentric projection T_eps(X) = diag(r)^-1 P Y  (Cor. 4).
    pub fn barycentric(&self) -> Result<Vec<f32>> {
        let y_real = {
            // real-row, real-col Y as a flat (m, d) for apply_pv
            let yp = self.ctx.y.as_f32()?;
            let (bd, d, m) = (self.ctx.bucket.d, self.ctx.d, self.ctx.m);
            let mut out = Vec::with_capacity(m * d);
            for j in 0..m {
                out.extend_from_slice(&yp[j * bd..j * bd + d]);
            }
            out
        };
        let (py, r) = self.apply_pv(&y_real, self.ctx.d)?;
        let d = self.ctx.d;
        let mut t = py;
        for i in 0..self.ctx.n {
            let ri = r[i].max(1e-38);
            for c in 0..d {
                t[i * d + c] /= ri;
            }
        }
        Ok(t)
    }

    pub fn eps(&self) -> f32 {
        self.ctx.eps
    }

    /// Build the prepared Schur operator for CG loops (hot path).
    pub fn schur_op(&self, ahat: &[f32], bhat: &[f32], tau: f32) -> Result<SchurOp<'e>> {
        SchurOp::new(self, ahat, bhat, tau)
    }
}

/// The damped Schur-complement matvec with every static input frozen in a
/// [`PreparedCall`]: each CG iteration supplies only the (m,) iterate.
/// This is the L3 hot-path optimization of the CG loop -- the solve
/// performs (2 K_CG) transport applications (Thm. 5), so per-call input
/// rebuilding dominated the naive path.
pub struct SchurOp<'e> {
    call: PreparedCall<'e>,
    ctx_m: usize,
    bucket_m: usize,
}

impl<'e> SchurOp<'e> {
    fn new(t: &Transport<'e>, ahat: &[f32], bhat: &[f32], tau: f32) -> Result<Self> {
        let slots = vec![
            Some(t.ctx.x.clone()),
            Some(t.ctx.y.clone()),
            Some(t.fhat_p.clone()),
            Some(t.ghat_p.clone()),
            Some(t.ctx.a.clone()),
            Some(t.ctx.b.clone()),
            Some(t.ctx.pad_n(ahat, 0.0)),
            Some(t.ctx.pad_m(bhat, 0.0)),
            None, // w2 -- the CG iterate, streamed per call
            Some(Tensor::scalar(tau)),
            Some(t.eps.clone()),
        ];
        Ok(SchurOp {
            call: PreparedCall::new(t.backend, t.ctx.key("schur_matvec"), slots),
            ctx_m: t.ctx.m,
            bucket_m: t.ctx.bucket.m,
        })
    }

    /// S_tau w (eq. 30) -- one fused op call, one small upload.
    pub fn matvec(&self, w2: &[f32]) -> Result<Vec<f32>> {
        let mut padded = vec![0.0f32; self.bucket_m];
        padded[..w2.len()].copy_from_slice(w2);
        let outs = self.call.call(&[Tensor::vector(padded)])?;
        Ok(outs[0].as_f32()?[..self.ctx_m].to_vec())
    }
}
