//! Problem definition: two weighted point clouds + regularization strength.

use anyhow::{bail, Result};

/// A discrete EOT instance (paper eq. 1): source (X, a), target (Y, b),
/// squared-Euclidean cost, regularization eps.
#[derive(Clone, Debug)]
pub struct OtProblem {
    /// n x d row-major source points.
    pub x: Vec<f32>,
    /// m x d row-major target points.
    pub y: Vec<f32>,
    /// source weights on the simplex.
    pub a: Vec<f32>,
    /// target weights on the simplex.
    pub b: Vec<f32>,
    pub n: usize,
    pub m: usize,
    pub d: usize,
    pub eps: f32,
}

impl OtProblem {
    pub fn new(
        x: Vec<f32>,
        y: Vec<f32>,
        a: Vec<f32>,
        b: Vec<f32>,
        n: usize,
        m: usize,
        d: usize,
        eps: f32,
    ) -> Result<Self> {
        if x.len() != n * d || y.len() != m * d {
            bail!("point array sizes do not match (n, m, d)");
        }
        if a.len() != n || b.len() != m {
            bail!("weight lengths do not match n/m");
        }
        if eps <= 0.0 {
            bail!("eps must be positive");
        }
        for (nm, w) in [("a", &a), ("b", &b)] {
            let s: f32 = w.iter().sum();
            if (s - 1.0).abs() > 1e-3 {
                bail!("weights {nm} sum to {s}, expected 1");
            }
            if w.iter().any(|&v| v < 0.0) {
                bail!("weights {nm} contain negative entries");
            }
        }
        Ok(Self { x, y, a, b, n, m, d, eps })
    }

    /// Uniform weights 1/n, 1/m (the paper's benchmark setting).
    pub fn uniform(x: Vec<f32>, y: Vec<f32>, n: usize, m: usize, d: usize, eps: f32) -> Result<Self> {
        let a = vec![1.0 / n as f32; n];
        let b = vec![1.0 / m as f32; m];
        Self::new(x, y, a, b, n, m, d, eps)
    }

    /// Cosine-distance EOT (paper section 3.1 "Scope of cost structure"):
    /// on L2-normalized inputs, 1 - <x, y> = 1/2 |x - y|^2, so cosine-cost
    /// EOT at `eps` is exactly squared-Euclidean EOT at `2 eps` with the
    /// objective halved.  This constructor normalizes the rows and adjusts
    /// eps; halve the reported dual cost via [`cosine_cost`] to recover the
    /// cosine-cost OT value.
    pub fn cosine(
        x: Vec<f32>,
        y: Vec<f32>,
        a: Vec<f32>,
        b: Vec<f32>,
        n: usize,
        m: usize,
        d: usize,
        eps: f32,
    ) -> Result<Self> {
        let normalize = |pts: &mut Vec<f32>, rows: usize| {
            for i in 0..rows {
                let row = &mut pts[i * d..(i + 1) * d];
                let norm: f32 = row.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-12);
                row.iter_mut().for_each(|v| *v /= norm);
            }
        };
        let (mut x, mut y) = (x, y);
        normalize(&mut x, n);
        normalize(&mut y, m);
        Self::new(x, y, a, b, n, m, d, 2.0 * eps)
    }

    /// Squared norms |x_i|^2 (the alpha shift of Prop. 1).
    pub fn alpha(&self) -> Vec<f32> {
        sqnorms(&self.x, self.n, self.d)
    }

    /// Squared norms |y_j|^2 (the beta shift).
    pub fn beta(&self) -> Vec<f32> {
        sqnorms(&self.y, self.m, self.d)
    }

    /// Squared diameter estimate (for eps-annealing start).
    pub fn sq_diameter(&self) -> f32 {
        let mut lo = vec![f32::INFINITY; self.d];
        let mut hi = vec![f32::NEG_INFINITY; self.d];
        for pts in [&self.x, &self.y] {
            for row in pts.chunks(self.d) {
                for (t, &v) in row.iter().enumerate() {
                    lo[t] = lo[t].min(v);
                    hi[t] = hi[t].max(v);
                }
            }
        }
        lo.iter().zip(&hi).map(|(l, h)| (h - l) * (h - l)).sum()
    }
}

/// Recover the cosine-cost OT value from the squared-Euclidean surrogate's
/// dual cost (see [`OtProblem::cosine`]).
pub fn cosine_cost(sq_dual_cost: f64) -> f64 {
    sq_dual_cost / 2.0
}

pub fn sqnorms(pts: &[f32], n: usize, d: usize) -> Vec<f32> {
    (0..n)
        .map(|i| pts[i * d..(i + 1) * d].iter().map(|v| v * v).sum())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> OtProblem {
        OtProblem::uniform(vec![0.0, 0.0, 1.0, 1.0], vec![1.0, 0.0, 0.0, 1.0], 2, 2, 2, 0.1).unwrap()
    }

    #[test]
    fn alpha_beta() {
        let p = tiny();
        assert_eq!(p.alpha(), vec![0.0, 2.0]);
        assert_eq!(p.beta(), vec![1.0, 1.0]);
    }

    #[test]
    fn diameter() {
        let p = tiny();
        assert!((p.sq_diameter() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn rejects_bad_weights() {
        assert!(OtProblem::new(
            vec![0.0; 4],
            vec![0.0; 4],
            vec![0.9, 0.9],
            vec![0.5, 0.5],
            2, 2, 2, 0.1
        )
        .is_err());
    }

    #[test]
    fn rejects_bad_eps() {
        assert!(OtProblem::uniform(vec![0.0; 4], vec![0.0; 4], 2, 2, 2, 0.0).is_err());
    }
}
