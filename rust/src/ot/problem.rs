//! Problem definition: two weighted point clouds + regularization strength.

use anyhow::{bail, Result};

/// A discrete EOT instance (paper eq. 1): source (X, a), target (Y, b),
/// squared-Euclidean cost, regularization eps.
#[derive(Clone, Debug)]
pub struct OtProblem {
    /// n x d row-major source points.
    pub x: Vec<f32>,
    /// m x d row-major target points.
    pub y: Vec<f32>,
    /// source weights on the simplex.
    pub a: Vec<f32>,
    /// target weights on the simplex.
    pub b: Vec<f32>,
    pub n: usize,
    pub m: usize,
    pub d: usize,
    pub eps: f32,
}

impl OtProblem {
    pub fn new(
        x: Vec<f32>,
        y: Vec<f32>,
        a: Vec<f32>,
        b: Vec<f32>,
        n: usize,
        m: usize,
        d: usize,
        eps: f32,
    ) -> Result<Self> {
        if x.len() != n * d || y.len() != m * d {
            bail!("point array sizes do not match (n, m, d)");
        }
        if a.len() != n || b.len() != m {
            bail!("weight lengths do not match n/m");
        }
        if eps <= 0.0 {
            bail!("eps must be positive");
        }
        for (nm, w) in [("a", &a), ("b", &b)] {
            let s: f32 = w.iter().sum();
            if (s - 1.0).abs() > 1e-3 {
                bail!("weights {nm} sum to {s}, expected 1");
            }
            if w.iter().any(|&v| v < 0.0) {
                bail!("weights {nm} contain negative entries");
            }
        }
        Ok(Self { x, y, a, b, n, m, d, eps })
    }

    /// Uniform weights 1/n, 1/m (the paper's benchmark setting).
    pub fn uniform(x: Vec<f32>, y: Vec<f32>, n: usize, m: usize, d: usize, eps: f32) -> Result<Self> {
        let a = vec![1.0 / n as f32; n];
        let b = vec![1.0 / m as f32; m];
        Self::new(x, y, a, b, n, m, d, eps)
    }

    /// Cosine-distance EOT (paper section 3.1 "Scope of cost structure"):
    /// on L2-normalized inputs, 1 - <x, y> = 1/2 |x - y|^2, so cosine-cost
    /// EOT at `eps` is exactly squared-Euclidean EOT at `2 eps` with the
    /// objective halved.  This constructor normalizes the rows and adjusts
    /// eps; halve the reported dual cost via [`cosine_cost`] to recover the
    /// cosine-cost OT value.
    pub fn cosine(
        x: Vec<f32>,
        y: Vec<f32>,
        a: Vec<f32>,
        b: Vec<f32>,
        n: usize,
        m: usize,
        d: usize,
        eps: f32,
    ) -> Result<Self> {
        let normalize = |pts: &mut Vec<f32>, rows: usize| {
            for i in 0..rows {
                let row = &mut pts[i * d..(i + 1) * d];
                let norm: f32 = row.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-12);
                row.iter_mut().for_each(|v| *v /= norm);
            }
        };
        let (mut x, mut y) = (x, y);
        normalize(&mut x, n);
        normalize(&mut y, m);
        Self::new(x, y, a, b, n, m, d, 2.0 * eps)
    }

    /// Squared norms |x_i|^2 (the alpha shift of Prop. 1).
    pub fn alpha(&self) -> Vec<f32> {
        sqnorms(&self.x, self.n, self.d)
    }

    /// Squared norms |y_j|^2 (the beta shift).
    pub fn beta(&self) -> Vec<f32> {
        sqnorms(&self.y, self.m, self.d)
    }

    /// Squared diameter estimate (for eps-annealing start).
    pub fn sq_diameter(&self) -> f32 {
        let mut lo = vec![f32::INFINITY; self.d];
        let mut hi = vec![f32::NEG_INFINITY; self.d];
        for pts in [&self.x, &self.y] {
            for row in pts.chunks(self.d) {
                for (t, &v) in row.iter().enumerate() {
                    lo[t] = lo[t].min(v);
                    hi[t] = hi[t].max(v);
                }
            }
        }
        lo.iter().zip(&hi).map(|(l, h)| (h - l) * (h - l)).sum()
    }
}

/// Recover the cosine-cost OT value from the squared-Euclidean surrogate's
/// dual cost (see [`OtProblem::cosine`]).
pub fn cosine_cost(sq_dual_cost: f64) -> f64 {
    sq_dual_cost / 2.0
}

/// Sentinel in a packed row→problem map marking a wall row that belongs to
/// no problem (see [`BatchedProblem`]).
pub const BATCH_WALL: u32 = u32::MAX;

/// B small EOT instances packed into one contiguous buffer set, so a
/// backend can solve all of them in a single fused pass (one pool fan-out
/// over the packed row range instead of B).
///
/// ## Packing layout
///
/// Problem `p`'s source points occupy packed rows
/// `[row_off[p], row_off[p] + n[p])` of `x` (and the matching entries of
/// `a`); its target points occupy packed columns
/// `[col_off[p], col_off[p] + m[p])` of `y` / `b`.  Between consecutive
/// problems sits exactly one **wall** row/column: zero points with weight
/// `0.0`.  Zero weight means the wall's column bias is `NEG_INF` under the
/// kernels' masking contract (`exp(NEG_INF - max) == 0.0` exactly), so even
/// if a tile or a misrouted loop ever touched a wall it would contribute
/// bitwise-nothing to any reduction.  The primary isolation mechanism is
/// stronger still: batched kernels restrict every packed row's column loop
/// to its own problem's segment, so no tile ever mixes neighbors; the walls
/// are the belt-and-braces backstop that turns a hypothetical indexing bug
/// into a no-op instead of silent cross-problem contamination.
///
/// `eps` is carried per problem: the shape-class router coalesces jobs by
/// (n, m, d) envelope only, so instances in one batch may regularize
/// differently.
#[derive(Clone, Debug)]
pub struct BatchedProblem {
    /// Packed source points, `rows() x d` row-major (walls zeroed).
    pub x: Vec<f32>,
    /// Packed target points, `cols() x d` row-major (walls zeroed).
    pub y: Vec<f32>,
    /// Packed source weights, length `rows()` (walls `0.0`).
    pub a: Vec<f32>,
    /// Packed target weights, length `cols()` (walls `0.0`).
    pub b: Vec<f32>,
    /// Per-problem regularization strengths, length B.
    pub eps: Vec<f32>,
    /// Per-problem source sizes, length B.
    pub n: Vec<usize>,
    /// Per-problem target sizes, length B.
    pub m: Vec<usize>,
    /// Packed start row of each problem, length B (strictly increasing,
    /// segments disjoint with one wall row between neighbors).
    pub row_off: Vec<usize>,
    /// Packed start column of each problem, length B.
    pub col_off: Vec<usize>,
    /// Shared point dimension.
    pub d: usize,
}

impl BatchedProblem {
    /// Pack `probs` (all sharing one `d`) into contiguous buffers with one
    /// wall row/column between consecutive problems.  Point and weight
    /// slices are copied verbatim, so [`Self::problem`] recovers every
    /// input bit exactly.
    pub fn pack(probs: &[&OtProblem]) -> Result<Self> {
        if probs.is_empty() {
            bail!("cannot pack an empty batch");
        }
        let d = probs[0].d;
        if probs.iter().any(|p| p.d != d) {
            bail!("batched problems must share d");
        }
        let bsz = probs.len();
        let total_rows: usize = probs.iter().map(|p| p.n).sum::<usize>() + (bsz - 1);
        let total_cols: usize = probs.iter().map(|p| p.m).sum::<usize>() + (bsz - 1);
        let mut out = Self {
            x: vec![0.0; total_rows * d],
            y: vec![0.0; total_cols * d],
            a: vec![0.0; total_rows],
            b: vec![0.0; total_cols],
            eps: Vec::with_capacity(bsz),
            n: Vec::with_capacity(bsz),
            m: Vec::with_capacity(bsz),
            row_off: Vec::with_capacity(bsz),
            col_off: Vec::with_capacity(bsz),
            d,
        };
        let (mut r0, mut c0) = (0usize, 0usize);
        for p in probs {
            out.row_off.push(r0);
            out.col_off.push(c0);
            out.n.push(p.n);
            out.m.push(p.m);
            out.eps.push(p.eps);
            out.x[r0 * d..(r0 + p.n) * d].copy_from_slice(&p.x);
            out.y[c0 * d..(c0 + p.m) * d].copy_from_slice(&p.y);
            out.a[r0..r0 + p.n].copy_from_slice(&p.a);
            out.b[c0..c0 + p.m].copy_from_slice(&p.b);
            r0 += p.n + 1; // +1 skips the wall row (stays zeroed)
            c0 += p.m + 1;
        }
        Ok(out)
    }

    /// Number of packed problems B.
    pub fn len(&self) -> usize {
        self.n.len()
    }

    /// True when the batch holds no problems (never after a successful
    /// [`Self::pack`]).
    pub fn is_empty(&self) -> bool {
        self.n.is_empty()
    }

    /// Total packed rows including walls.
    pub fn rows(&self) -> usize {
        self.a.len()
    }

    /// Total packed columns including walls.
    pub fn cols(&self) -> usize {
        self.b.len()
    }

    /// Packed row range of problem `p`.
    pub fn row_range(&self, p: usize) -> std::ops::Range<usize> {
        self.row_off[p]..self.row_off[p] + self.n[p]
    }

    /// Packed column range of problem `p`.
    pub fn col_range(&self, p: usize) -> std::ops::Range<usize> {
        self.col_off[p]..self.col_off[p] + self.m[p]
    }

    /// Unpack problem `p` by slicing the packed buffers — bit-exact
    /// recovery of what [`Self::pack`] copied in (no re-validation, the
    /// inputs already passed [`OtProblem::new`]).
    pub fn problem(&self, p: usize) -> OtProblem {
        let (rr, cr) = (self.row_range(p), self.col_range(p));
        OtProblem {
            x: self.x[rr.start * self.d..rr.end * self.d].to_vec(),
            y: self.y[cr.start * self.d..cr.end * self.d].to_vec(),
            a: self.a[rr.clone()].to_vec(),
            b: self.b[cr].to_vec(),
            n: self.n[p],
            m: self.m[p],
            d: self.d,
            eps: self.eps[p],
        }
    }

    /// Packed row → owning problem map ([`BATCH_WALL`] on wall rows).
    pub fn row_prob_map(&self) -> Vec<u32> {
        let mut map = vec![BATCH_WALL; self.rows()];
        for p in 0..self.len() {
            map[self.row_range(p)].fill(p as u32);
        }
        map
    }

    /// Packed column → owning problem map ([`BATCH_WALL`] on wall columns).
    pub fn col_prob_map(&self) -> Vec<u32> {
        let mut map = vec![BATCH_WALL; self.cols()];
        for p in 0..self.len() {
            map[self.col_range(p)].fill(p as u32);
        }
        map
    }
}

pub fn sqnorms(pts: &[f32], n: usize, d: usize) -> Vec<f32> {
    (0..n)
        .map(|i| pts[i * d..(i + 1) * d].iter().map(|v| v * v).sum())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> OtProblem {
        OtProblem::uniform(vec![0.0, 0.0, 1.0, 1.0], vec![1.0, 0.0, 0.0, 1.0], 2, 2, 2, 0.1).unwrap()
    }

    #[test]
    fn alpha_beta() {
        let p = tiny();
        assert_eq!(p.alpha(), vec![0.0, 2.0]);
        assert_eq!(p.beta(), vec![1.0, 1.0]);
    }

    #[test]
    fn diameter() {
        let p = tiny();
        assert!((p.sq_diameter() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn rejects_bad_weights() {
        assert!(OtProblem::new(
            vec![0.0; 4],
            vec![0.0; 4],
            vec![0.9, 0.9],
            vec![0.5, 0.5],
            2, 2, 2, 0.1
        )
        .is_err());
    }

    #[test]
    fn rejects_bad_eps() {
        assert!(OtProblem::uniform(vec![0.0; 4], vec![0.0; 4], 2, 2, 2, 0.0).is_err());
    }

    #[test]
    fn batched_pack_layout_and_bitwise_unpack() {
        let p0 = OtProblem::uniform(vec![0.5; 2 * 3], vec![0.25; 4 * 3], 2, 4, 3, 0.1).unwrap();
        let p1 = OtProblem::uniform(vec![-1.0; 3 * 3], vec![2.0; 2 * 3], 3, 2, 3, 0.3).unwrap();
        let batch = BatchedProblem::pack(&[&p0, &p1]).unwrap();
        assert_eq!(batch.len(), 2);
        // one wall row/column between the two problems
        assert_eq!(batch.rows(), 2 + 3 + 1);
        assert_eq!(batch.cols(), 4 + 2 + 1);
        assert_eq!(batch.row_off, vec![0, 3]);
        assert_eq!(batch.col_off, vec![0, 5]);
        // the wall carries zero weight and zero points
        assert_eq!(batch.a[2], 0.0);
        assert_eq!(batch.b[4], 0.0);
        assert!(batch.x[2 * 3..3 * 3].iter().all(|&v| v == 0.0));
        // bit-exact round trip
        for (p, orig) in [(0, &p0), (1, &p1)] {
            let got = batch.problem(p);
            assert_eq!(got.x, orig.x);
            assert_eq!(got.y, orig.y);
            assert_eq!(got.a, orig.a);
            assert_eq!(got.b, orig.b);
            assert_eq!((got.n, got.m, got.d), (orig.n, orig.m, orig.d));
            assert_eq!(got.eps.to_bits(), orig.eps.to_bits());
        }
        let rmap = batch.row_prob_map();
        assert_eq!(rmap, vec![0, 0, BATCH_WALL, 1, 1, 1]);
        let cmap = batch.col_prob_map();
        assert_eq!(cmap, vec![0, 0, 0, 0, BATCH_WALL, 1, 1]);
    }

    #[test]
    fn batched_pack_rejects_empty_and_mixed_d() {
        assert!(BatchedProblem::pack(&[]).is_err());
        let p0 = OtProblem::uniform(vec![0.0; 4], vec![0.0; 4], 2, 2, 2, 0.1).unwrap();
        let p1 = OtProblem::uniform(vec![0.0; 6], vec![0.0; 6], 2, 2, 3, 0.1).unwrap();
        assert!(BatchedProblem::pack(&[&p0, &p1]).is_err());
    }

    #[test]
    fn batched_pack_singleton_has_no_walls() {
        let p0 = OtProblem::uniform(vec![0.0; 4], vec![0.0; 4], 2, 2, 2, 0.1).unwrap();
        let batch = BatchedProblem::pack(&[&p0]).unwrap();
        assert_eq!(batch.rows(), 2);
        assert_eq!(batch.cols(), 2);
        assert_eq!(batch.row_prob_map(), vec![0, 0]);
    }
}
