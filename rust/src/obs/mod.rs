//! Deep observability: measured IO accounting, job-lifecycle tracing, and
//! metrics exposition.
//!
//! Three coupled layers, all dependency-free and deterministic where it
//! matters:
//!
//! * **[`iostats`]** — [`IoStats`] counters (x/y/dual bytes read, tiles,
//!   LSE evaluations, flop estimate, pool busy/idle/steal nanos) charged
//!   analytically at the native backend's call chokepoints and surfaced
//!   through `runtime::ComputeBackend::io_stats`, per solve in
//!   `ot::solver::SolveReport::io`, and per service in
//!   `coordinator::Metrics`.  The measured counterpart of
//!   `iomodel::plans::analyze` (`repro profile --measured`).
//! * **[`trace`]** — a bounded [`TraceRing`] of typed [`TraceEvent`]s
//!   covering a job's admission → queue → batch → actor → solve-stage →
//!   completion journey, timestamped only through
//!   `coordinator::clock::Clock` (deterministic under `VirtualClock`),
//!   exportable as JSON-lines or chrome-tracing via `repro trace`.
//! * **[`exporter`]** — a hand-rolled std-only HTTP listener serving
//!   `Snapshot::render_prometheus()` at `/metrics` and the JSON snapshot
//!   at `/metrics.json` (`repro serve --metrics-addr`).
//!
//! ## The knob
//!
//! One spec string, from `service.obs` in the config (which itself
//! defaults from `FLASH_SINKHORN_OBS`), parsed by [`ObsMode::parse`]:
//!
//! | spec | meaning |
//! |------|---------|
//! | `"counters"` (default) | IO counters on, tracing off |
//! | `"off"` | all instrumentation off |
//! | `"trace"` | counters + lifecycle ring (capacity 4096) |
//! | `"trace:N"` | counters + lifecycle ring of capacity N |
//!
//! Counters never touch the numeric loops (charging is analytic over loop
//! geometry), so no mode perturbs the bitwise-determinism pins; `"off"`
//! exists to make the counter overhead itself measurable
//! (`obs_overhead_pct` in the bench smoke).

pub mod exporter;
pub mod iostats;
pub mod trace;

pub use exporter::MetricsFormat;
pub use iostats::{AtomicIoStats, IoStats};
pub use trace::{TraceEvent, TraceKind, TraceRing, DEFAULT_TRACE_CAPACITY};

use std::sync::OnceLock;

use anyhow::{anyhow, bail, Result};

/// Parsed observability mode (see the module docs for the spec grammar).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ObsMode {
    /// All instrumentation off.
    Off,
    /// IO/work counters on, lifecycle tracing off (the default).
    Counters,
    /// Counters plus a lifecycle trace ring of the given capacity.
    Trace {
        /// Ring capacity in events.
        capacity: usize,
    },
}

impl ObsMode {
    /// Parse an obs spec: `off` | `counters` | `trace` | `trace:N`
    /// (plus `on`/`1`/`true`/`0`/`false` aliases, and `""` = default).
    pub fn parse(spec: &str) -> Result<ObsMode> {
        match spec.trim() {
            "" | "counters" | "on" | "1" | "true" => Ok(ObsMode::Counters),
            "off" | "0" | "false" => Ok(ObsMode::Off),
            "trace" => Ok(ObsMode::Trace { capacity: DEFAULT_TRACE_CAPACITY }),
            other => match other.strip_prefix("trace:") {
                Some(num) => {
                    let capacity = num
                        .parse::<usize>()
                        .ok()
                        .filter(|&c| c > 0)
                        .ok_or_else(|| {
                            anyhow!("obs spec '{other}': trace capacity must be a positive integer")
                        })?;
                    Ok(ObsMode::Trace { capacity })
                }
                None => bail!(
                    "unknown obs spec '{other}' (expected off | counters | trace[:capacity])"
                ),
            },
        }
    }

    /// Whether counter instrumentation is on in this mode.
    pub fn counters(&self) -> bool {
        !matches!(self, ObsMode::Off)
    }

    /// Build the trace ring this mode calls for (None = tracing off).
    pub fn ring(&self) -> Option<TraceRing> {
        match self {
            ObsMode::Trace { capacity } => Some(TraceRing::new(*capacity)),
            _ => None,
        }
    }
}

/// Process-wide default for backend counter instrumentation, read once
/// from `FLASH_SINKHORN_OBS` (only `off`/`0`/`false` disable; anything
/// else, including unset, is on).  Backends constructed outside a service
/// (library users, the CLI solve path) consult this; the bench's overhead
/// measurement overrides it per backend via
/// `native::NativeBackend::with_counters`.
pub fn counters_enabled() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| {
        !matches!(
            std::env::var("FLASH_SINKHORN_OBS").as_deref(),
            Ok("off") | Ok("0") | Ok("false")
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obs_mode_specs_parse() {
        assert_eq!(ObsMode::parse("").unwrap(), ObsMode::Counters);
        assert_eq!(ObsMode::parse("counters").unwrap(), ObsMode::Counters);
        assert_eq!(ObsMode::parse("on").unwrap(), ObsMode::Counters);
        assert_eq!(ObsMode::parse("off").unwrap(), ObsMode::Off);
        assert_eq!(ObsMode::parse("0").unwrap(), ObsMode::Off);
        assert_eq!(
            ObsMode::parse("trace").unwrap(),
            ObsMode::Trace { capacity: DEFAULT_TRACE_CAPACITY }
        );
        assert_eq!(ObsMode::parse("trace:16").unwrap(), ObsMode::Trace { capacity: 16 });
        assert!(ObsMode::parse("trace:0").is_err());
        assert!(ObsMode::parse("trace:-3").is_err());
        assert!(ObsMode::parse("verbose").is_err());
    }

    #[test]
    fn mode_helpers_match_the_spec() {
        assert!(ObsMode::Counters.counters());
        assert!(!ObsMode::Off.counters());
        assert!(ObsMode::Counters.ring().is_none());
        assert!(ObsMode::Off.ring().is_none());
        let ring = ObsMode::Trace { capacity: 7 }.ring().unwrap();
        assert_eq!(ring.capacity(), 7);
    }
}
