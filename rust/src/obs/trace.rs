//! Structured job-lifecycle tracing: a bounded ring of typed events.
//!
//! Every event a job emits on its admission → queue → batch → actor →
//! solve-stage → completion journey is a [`TraceEvent`]: a job correlation
//! id (`seq`), a timestamp, and a typed [`TraceKind`].  Timestamps come
//! *only* through `coordinator::clock::Clock`, so a service running on a
//! `VirtualClock` produces bit-for-bit reproducible traces (pinned by
//! `tests/serving_stress.rs`).
//!
//! The [`TraceRing`] is a fixed-capacity deque behind a mutex: pushes are
//! O(1), the oldest events are dropped (and counted) under overflow, and
//! the ring is only ever allocated when tracing is enabled
//! (`service.obs = "trace[:capacity]"`), so the default serving path pays
//! nothing.
//!
//! Two export formats, both hand-rolled over [`crate::util::json`]:
//! JSON-lines ([`render_jsonl`], one event object per line, grep-friendly)
//! and the chrome-tracing / Perfetto `traceEvents` envelope
//! ([`render_chrome`], instant events keyed by job `seq` as the track id).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::json::{self, Json};

/// Ring capacity used by the bare `"trace"` spec (no `:capacity` suffix).
pub const DEFAULT_TRACE_CAPACITY: usize = 4096;

/// One job-lifecycle event: which job (`seq`), when (`ts`, from the
/// service clock), and what happened (`kind`).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Job correlation id, assigned at submission.
    pub seq: u64,
    /// Service-clock timestamp (deterministic under `VirtualClock`).
    pub ts: Duration,
    /// What happened.
    pub kind: TraceKind,
}

/// The typed lifecycle stages a job can report.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceKind {
    /// Passed admission control and entered the service.
    Admitted {
        /// Tenant label (`"-"` for anonymous jobs).
        tenant: String,
        /// Shape-class key the job batches under.
        class: String,
    },
    /// Turned away at admission (backpressure / rate limit / inflight cap).
    Rejected {
        /// Tenant label (`"-"` for anonymous jobs).
        tenant: String,
        /// Human-readable rejection reason (the `Rejection` display text).
        reason: String,
    },
    /// Entered its class queue.
    Enqueued {
        /// Shape-class key.
        class: String,
        /// Queue depth for that class after the push.
        depth: usize,
    },
    /// Popped as part of a same-class batch.
    Batched {
        /// Shape-class key.
        class: String,
        /// Number of jobs coalesced into the batch.
        size: usize,
    },
    /// Handed to a backend actor for execution.
    Dispatched {
        /// Actor slot index executing the batch.
        actor: usize,
    },
    /// Warm-start dual cache produced usable duals.
    WarmHit {
        /// Iterations saved vs the cached entry's cold solve.
        saved_iters: usize,
    },
    /// Warm-start dual cache was consulted and missed.
    WarmMiss,
    /// A solver stage began (reconstructed from `SolveReport::stages`;
    /// stage timestamps bracket the whole solve).
    StageStarted {
        /// Stage kind (`"anneal"`, `"final"`, ...).
        stage: &'static str,
        /// Regularization eps the stage ran at.
        eps: f32,
    },
    /// A solver stage finished.
    StageFinished {
        /// Stage kind (`"anneal"`, `"final"`, ...).
        stage: &'static str,
        /// Regularization eps the stage ran at.
        eps: f32,
        /// Sinkhorn iterations the stage used.
        iters: usize,
        /// Sup-norm potential change when the stage stopped.
        final_delta: f32,
    },
    /// The job finished and its response was sent.
    Completed {
        /// Total Sinkhorn iterations across stages.
        iters: usize,
        /// Entropic OT cost of the solution.
        cost: f64,
    },
}

impl TraceKind {
    /// Stable event name shared by both export formats.
    pub fn name(&self) -> &'static str {
        match self {
            TraceKind::Admitted { .. } => "admitted",
            TraceKind::Rejected { .. } => "rejected",
            TraceKind::Enqueued { .. } => "enqueued",
            TraceKind::Batched { .. } => "batched",
            TraceKind::Dispatched { .. } => "dispatched",
            TraceKind::WarmHit { .. } => "warm_hit",
            TraceKind::WarmMiss => "warm_miss",
            TraceKind::StageStarted { .. } => "stage_started",
            TraceKind::StageFinished { .. } => "stage_finished",
            TraceKind::Completed { .. } => "completed",
        }
    }

    /// Per-variant payload fields (the `args` of both export formats).
    fn args(&self) -> Vec<(&'static str, Json)> {
        match self {
            TraceKind::Admitted { tenant, class } => {
                vec![("tenant", json::s(tenant)), ("class", json::s(class))]
            }
            TraceKind::Rejected { tenant, reason } => {
                vec![("tenant", json::s(tenant)), ("reason", json::s(reason))]
            }
            TraceKind::Enqueued { class, depth } => {
                vec![("class", json::s(class)), ("depth", json::num(*depth as f64))]
            }
            TraceKind::Batched { class, size } => {
                vec![("class", json::s(class)), ("size", json::num(*size as f64))]
            }
            TraceKind::Dispatched { actor } => vec![("actor", json::num(*actor as f64))],
            TraceKind::WarmHit { saved_iters } => {
                vec![("saved_iters", json::num(*saved_iters as f64))]
            }
            TraceKind::WarmMiss => vec![],
            TraceKind::StageStarted { stage, eps } => {
                vec![("stage", json::s(stage)), ("eps", json::num(f64::from(*eps)))]
            }
            TraceKind::StageFinished { stage, eps, iters, final_delta } => vec![
                ("stage", json::s(stage)),
                ("eps", json::num(f64::from(*eps))),
                ("iters", json::num(*iters as f64)),
                ("final_delta", json::num(f64::from(*final_delta))),
            ],
            TraceKind::Completed { iters, cost } => {
                vec![("iters", json::num(*iters as f64)), ("cost", json::num(*cost))]
            }
        }
    }
}

/// Bounded multi-producer event ring: pushes drop the oldest event once
/// `capacity` is reached (overflow is counted, never blocking).
#[derive(Debug)]
pub struct TraceRing {
    buf: Mutex<VecDeque<TraceEvent>>,
    capacity: usize,
    dropped: AtomicU64,
}

impl TraceRing {
    /// A ring holding at most `capacity` events (minimum 1).
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        Self {
            buf: Mutex::new(VecDeque::with_capacity(capacity)),
            capacity,
            dropped: AtomicU64::new(0),
        }
    }

    /// Append an event, evicting the oldest when full.
    pub fn push(&self, ev: TraceEvent) {
        let mut buf = self.buf.lock().unwrap_or_else(|e| e.into_inner());
        if buf.len() == self.capacity {
            buf.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        buf.push_back(ev);
    }

    /// Take every buffered event (oldest first), leaving the ring empty.
    pub fn drain(&self) -> Vec<TraceEvent> {
        let mut buf = self.buf.lock().unwrap_or_else(|e| e.into_inner());
        buf.drain(..).collect()
    }

    /// Events currently buffered.
    pub fn len(&self) -> usize {
        self.buf.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    /// True when no events are buffered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Configured capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Events evicted under overflow since construction.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

fn event_json(e: &TraceEvent) -> Json {
    let mut pairs = vec![
        ("event", json::s(e.kind.name())),
        ("seq", json::num(e.seq as f64)),
        ("ts_us", json::num(e.ts.as_micros() as f64)),
    ];
    pairs.extend(e.kind.args());
    json::obj(pairs)
}

/// JSON-lines export: one compact event object per line, in ring order.
pub fn render_jsonl(events: &[TraceEvent]) -> String {
    let mut out = String::new();
    for e in events {
        out.push_str(&event_json(e).to_string_compact());
        out.push('\n');
    }
    out
}

/// Chrome-tracing (`chrome://tracing` / Perfetto) export: instant events
/// in a `traceEvents` envelope, one track (`tid`) per job `seq`.
pub fn render_chrome(events: &[TraceEvent]) -> String {
    let evs: Vec<Json> = events
        .iter()
        .map(|e| {
            json::obj(vec![
                ("name", json::s(e.kind.name())),
                ("ph", json::s("i")),
                ("ts", json::num(e.ts.as_micros() as f64)),
                ("pid", json::num(1.0)),
                ("tid", json::num(e.seq as f64)),
                ("s", json::s("t")),
                ("args", json::obj(e.kind.args())),
            ])
        })
        .collect();
    json::obj(vec![("displayTimeUnit", json::s("ms")), ("traceEvents", Json::Arr(evs))])
        .to_string_compact()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(seq: u64, us: u64, kind: TraceKind) -> TraceEvent {
        TraceEvent { seq, ts: Duration::from_micros(us), kind }
    }

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        let ring = TraceRing::new(2);
        assert!(ring.is_empty());
        for i in 0..5 {
            ring.push(ev(i, i * 10, TraceKind::WarmMiss));
        }
        assert_eq!((ring.len(), ring.capacity(), ring.dropped()), (2, 2, 3));
        let drained = ring.drain();
        assert_eq!(drained.iter().map(|e| e.seq).collect::<Vec<_>>(), vec![3, 4]);
        assert!(ring.is_empty());
    }

    #[test]
    fn jsonl_lines_parse_and_carry_payload() {
        let events = [
            ev(1, 5, TraceKind::Admitted { tenant: "t0".into(), class: "n24".into() }),
            ev(1, 7, TraceKind::Completed { iters: 12, cost: 0.5 }),
        ];
        let text = render_jsonl(&events);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.req("event").unwrap().as_str().unwrap(), "admitted");
        assert_eq!(first.req("tenant").unwrap().as_str().unwrap(), "t0");
        assert_eq!(first.req("ts_us").unwrap().as_usize().unwrap(), 5);
        let second = Json::parse(lines[1]).unwrap();
        assert_eq!(second.req("iters").unwrap().as_usize().unwrap(), 12);
    }

    #[test]
    fn chrome_envelope_parses_with_one_entry_per_event() {
        let events = [
            ev(3, 1, TraceKind::Dispatched { actor: 2 }),
            ev(3, 2, TraceKind::WarmHit { saved_iters: 8 }),
        ];
        let v = Json::parse(&render_chrome(&events)).unwrap();
        let evs = v.req("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].req("ph").unwrap().as_str().unwrap(), "i");
        assert_eq!(evs[0].req("tid").unwrap().as_usize().unwrap(), 3);
        let args = evs[1].req("args").unwrap();
        assert_eq!(args.req("saved_iters").unwrap().as_usize().unwrap(), 8);
    }
}
