//! Measured IO / work counters for compute backends.
//!
//! [`IoStats`] is the per-call / per-solve value type.  Byte counts are
//! *memory traffic under the kernels' tiling model* — a y tile is charged
//! once per row block in `lse_update` (it stays cache-resident across the
//! block) but once per row in `apply_rows` (which streams columns per
//! row) — not cache-hit-adjusted hardware counters.  This is the CPU
//! analogue of the HBM traffic `iomodel::plans::analyze` predicts for a
//! GPU, and the measured side of `repro profile --measured`.
//!
//! Counting is analytic over loop geometry (see
//! `crate::native::kernels::lse_update_io` and friends), charged at the
//! call chokepoints in `crate::native::NativeBackend`.  It therefore never
//! touches the numeric loops (bitwise determinism is unaffected), is itself
//! deterministic, and is exactly conservative: a fused k-step op charges
//! exactly k times the stats of a single step (pinned by
//! `tests/backend_parity.rs`).  The `pool_*_nanos` fields are the one
//! exception — wall-clock times from the worker pool and the service's
//! steal path, useful for utilization, never for determinism pins.
//!
//! [`AtomicIoStats`] is the interior-mutability accumulator backends thread
//! through their `&self` call paths.

use std::sync::atomic::{AtomicU64, Ordering};

/// Measured IO and work counters for one backend call, solve, or actor.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct IoStats {
    /// Bytes of row-side point coordinates (`x`) read.
    pub x_bytes: u64,
    /// Bytes of column-side data read (`y` tiles plus streamed `V`/`U`
    /// panels).
    pub y_bytes: u64,
    /// Bytes of dual-potential / column-bias vectors read.
    pub dual_bytes: u64,
    /// Bytes moved by the y-panel transpose/pack (`PackedTile`): source
    /// rows read plus zero-padded panels written.  A one-time layout
    /// transform, deliberately *not* part of [`IoStats::read_bytes`] — the
    /// streamed-traffic total the IO-model ratio compares keeps its
    /// meaning.
    pub pack_bytes: u64,
    /// Column tiles visited across all row blocks.
    pub tiles: u64,
    /// Online-LSE score evaluations (one per `(i, j)` pass).
    pub lse_evals: u64,
    /// Estimated floating-point ops (dot multiply-adds plus the LSE /
    /// accumulator update per score).
    pub flops: u64,
    /// Wall nanos the kernel pool spent inside parallel regions.
    pub pool_busy_nanos: u64,
    /// Wall nanos elapsed between consecutive parallel regions.
    pub pool_idle_nanos: u64,
    /// Wall nanos actors spent executing batches stolen from other actors
    /// (filled at the service layer, zero for bare backend calls).
    pub pool_steal_nanos: u64,
}

impl IoStats {
    /// Total bytes read (`x + y + dual`) — the measured analogue of the
    /// analytic model's `hbm_read_bytes`.
    pub fn read_bytes(&self) -> u64 {
        self.x_bytes + self.y_bytes + self.dual_bytes
    }

    /// Counter-wise `self - base` (saturating), turning two cumulative
    /// snapshots into a per-interval measurement.
    pub fn delta_since(&self, base: &IoStats) -> IoStats {
        IoStats {
            x_bytes: self.x_bytes.saturating_sub(base.x_bytes),
            y_bytes: self.y_bytes.saturating_sub(base.y_bytes),
            dual_bytes: self.dual_bytes.saturating_sub(base.dual_bytes),
            pack_bytes: self.pack_bytes.saturating_sub(base.pack_bytes),
            tiles: self.tiles.saturating_sub(base.tiles),
            lse_evals: self.lse_evals.saturating_sub(base.lse_evals),
            flops: self.flops.saturating_sub(base.flops),
            pool_busy_nanos: self.pool_busy_nanos.saturating_sub(base.pool_busy_nanos),
            pool_idle_nanos: self.pool_idle_nanos.saturating_sub(base.pool_idle_nanos),
            pool_steal_nanos: self.pool_steal_nanos.saturating_sub(base.pool_steal_nanos),
        }
    }

    /// Counter-wise accumulate.
    pub fn add(&mut self, other: &IoStats) {
        self.x_bytes += other.x_bytes;
        self.y_bytes += other.y_bytes;
        self.dual_bytes += other.dual_bytes;
        self.pack_bytes += other.pack_bytes;
        self.tiles += other.tiles;
        self.lse_evals += other.lse_evals;
        self.flops += other.flops;
        self.pool_busy_nanos += other.pool_busy_nanos;
        self.pool_idle_nanos += other.pool_idle_nanos;
        self.pool_steal_nanos += other.pool_steal_nanos;
    }

    /// True when every counter is zero (counters off, or a backend that
    /// does not measure).
    pub fn is_zero(&self) -> bool {
        *self == IoStats::default()
    }

    /// Counter-wise sum of per-problem deltas.  The batched backend path
    /// charges one fused total that must equal the per-problem sum
    /// exactly — integer counters make this an identity, not an
    /// approximation.
    pub fn sum<'a, I: IntoIterator<Item = &'a IoStats>>(parts: I) -> IoStats {
        let mut total = IoStats::default();
        for part in parts {
            total.add(part);
        }
        total
    }
}

/// Shared-state accumulator for [`IoStats`]: relaxed atomic adds on the
/// kernel call path, consistent-enough snapshots for reporting (counters
/// are monotone; readers tolerate mid-call tearing).
#[derive(Debug, Default)]
pub struct AtomicIoStats {
    x_bytes: AtomicU64,
    y_bytes: AtomicU64,
    dual_bytes: AtomicU64,
    pack_bytes: AtomicU64,
    tiles: AtomicU64,
    lse_evals: AtomicU64,
    flops: AtomicU64,
    pool_busy_nanos: AtomicU64,
    pool_idle_nanos: AtomicU64,
    pool_steal_nanos: AtomicU64,
}

impl AtomicIoStats {
    /// Accumulate one call's worth of counters.
    pub fn add(&self, s: &IoStats) {
        // skip the zero adds: most call sites charge only a few fields
        for (slot, v) in [
            (&self.x_bytes, s.x_bytes),
            (&self.y_bytes, s.y_bytes),
            (&self.dual_bytes, s.dual_bytes),
            (&self.pack_bytes, s.pack_bytes),
            (&self.tiles, s.tiles),
            (&self.lse_evals, s.lse_evals),
            (&self.flops, s.flops),
            (&self.pool_busy_nanos, s.pool_busy_nanos),
            (&self.pool_idle_nanos, s.pool_idle_nanos),
            (&self.pool_steal_nanos, s.pool_steal_nanos),
        ] {
            if v != 0 {
                slot.fetch_add(v, Ordering::Relaxed);
            }
        }
    }

    /// Current cumulative totals.
    pub fn snapshot(&self) -> IoStats {
        IoStats {
            x_bytes: self.x_bytes.load(Ordering::Relaxed),
            y_bytes: self.y_bytes.load(Ordering::Relaxed),
            dual_bytes: self.dual_bytes.load(Ordering::Relaxed),
            pack_bytes: self.pack_bytes.load(Ordering::Relaxed),
            tiles: self.tiles.load(Ordering::Relaxed),
            lse_evals: self.lse_evals.load(Ordering::Relaxed),
            flops: self.flops.load(Ordering::Relaxed),
            pool_busy_nanos: self.pool_busy_nanos.load(Ordering::Relaxed),
            pool_idle_nanos: self.pool_idle_nanos.load(Ordering::Relaxed),
            pool_steal_nanos: self.pool_steal_nanos.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(k: u64) -> IoStats {
        IoStats {
            x_bytes: k,
            y_bytes: 2 * k,
            dual_bytes: 3 * k,
            pack_bytes: 10 * k,
            tiles: 4 * k,
            lse_evals: 5 * k,
            flops: 6 * k,
            pool_busy_nanos: 7 * k,
            pool_idle_nanos: 8 * k,
            pool_steal_nanos: 9 * k,
        }
    }

    #[test]
    fn delta_and_add_are_inverse() {
        let base = sample(10);
        let mut cur = base;
        cur.add(&sample(3));
        assert_eq!(cur.delta_since(&base), sample(3));
        assert!(sample(0).is_zero());
        assert!(!sample(1).is_zero());
    }

    #[test]
    fn read_bytes_sums_the_three_streams_and_excludes_pack() {
        // pack_bytes is a layout transform, not streamed read traffic
        assert_eq!(sample(2).read_bytes(), 2 + 4 + 6);
    }

    #[test]
    fn delta_saturates_instead_of_wrapping() {
        // a fresh backend snapshot against a stale larger base must not wrap
        assert!(sample(1).delta_since(&sample(5)).is_zero());
    }

    #[test]
    fn atomic_accumulator_roundtrips() {
        let acc = AtomicIoStats::default();
        assert!(acc.snapshot().is_zero());
        acc.add(&sample(4));
        acc.add(&sample(1));
        assert_eq!(acc.snapshot(), sample(5));
    }
}
