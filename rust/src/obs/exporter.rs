//! Minimal hand-rolled HTTP/1.1 exposition listener.
//!
//! The workspace is offline (no hyper/axum/tiny-http), and the exposition
//! contract is tiny: answer `GET /metrics` with Prometheus text format and
//! `GET /metrics.json` with the JSON snapshot, one short-lived connection
//! per scrape.  So the listener is ~80 lines of std: accept, read the
//! request head, route on the path, write a `Content-Length`-framed
//! response, close.  Renders are produced by a caller-supplied closure so
//! this layer knows nothing about `coordinator::Metrics`.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::Duration;

use anyhow::{Context, Result};

/// Exposition formats the listener can answer with.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricsFormat {
    /// Prometheus text exposition format (`/metrics`).
    Prometheus,
    /// Compact JSON snapshot (`/metrics.json`).
    Json,
}

/// Bind `addr` and serve `render(format)` forever on a background thread.
///
/// Returns the bound address (pass port 0 to let the OS pick — used by the
/// tests).  The thread runs for the life of the process; scrapers poll, so
/// there is nothing to flush on shutdown.
pub fn spawn<F>(addr: &str, render: F) -> Result<SocketAddr>
where
    F: Fn(MetricsFormat) -> String + Send + Sync + 'static,
{
    let listener =
        TcpListener::bind(addr).with_context(|| format!("binding metrics listener on {addr}"))?;
    let local = listener.local_addr().context("resolving metrics listener address")?;
    std::thread::Builder::new()
        .name("fs-metrics".into())
        .spawn(move || {
            for stream in listener.incoming() {
                if let Ok(mut s) = stream {
                    let _ = answer(&mut s, &render);
                }
            }
        })
        .context("spawning metrics exporter thread")?;
    Ok(local)
}

/// Read one request head and write one framed response.  Any IO error just
/// drops the connection — a scraper retries on its next interval.
fn answer<F>(stream: &mut TcpStream, render: &F) -> std::io::Result<()>
where
    F: Fn(MetricsFormat) -> String,
{
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    let mut head = Vec::with_capacity(512);
    let mut buf = [0u8; 512];
    loop {
        let k = stream.read(&mut buf)?;
        if k == 0 {
            break;
        }
        head.extend_from_slice(&buf[..k]);
        // stop at the end of the header block; cap runaway requests
        if head.windows(4).any(|w| w == b"\r\n\r\n") || head.len() > 16 * 1024 {
            break;
        }
    }
    let line = String::from_utf8_lossy(&head);
    let path = line.split_whitespace().nth(1).unwrap_or("/").to_string();
    let (status, ctype, body) = match path.as_str() {
        "/metrics" => (
            "200 OK",
            "text/plain; version=0.0.4; charset=utf-8",
            render(MetricsFormat::Prometheus),
        ),
        "/metrics.json" => ("200 OK", "application/json", render(MetricsFormat::Json)),
        _ => (
            "404 Not Found",
            "text/plain; charset=utf-8",
            "not found: try /metrics or /metrics.json\n".to_string(),
        ),
    };
    write!(
        stream,
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get(addr: SocketAddr, path: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nHost: test\r\nConnection: close\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_both_formats_and_404s_elsewhere() {
        let addr = spawn("127.0.0.1:0", |f| match f {
            MetricsFormat::Prometheus => "fs_test_series 0\n".to_string(),
            MetricsFormat::Json => "{\"ok\":true}".to_string(),
        })
        .unwrap();
        let prom = get(addr, "/metrics");
        assert!(prom.starts_with("HTTP/1.1 200 OK"), "{prom}");
        assert!(prom.contains("text/plain; version=0.0.4"), "{prom}");
        assert!(prom.ends_with("fs_test_series 0\n"), "{prom}");
        let js = get(addr, "/metrics.json");
        assert!(js.contains("application/json"), "{js}");
        assert!(js.ends_with("{\"ok\":true}"), "{js}");
        let miss = get(addr, "/nope");
        assert!(miss.starts_with("HTTP/1.1 404"), "{miss}");
    }
}
