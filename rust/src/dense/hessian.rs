//! Dense data-space Hessian contraction with Moore-Penrose pseudoinverse:
//! the ground truth for HVP parity (paper section H.2.3, Tables 14/22).
//!
//! Implements Theorem 7 / eq. (6) literally in f64:
//!
//! ```text
//! T A = (1/eps) R^T H^+ (R A) + E A
//! ```
//! with H built from the *induced* marginals (section G.1) and H^+ via
//! Jacobi eigendecomposition (threshold 1e-10, as in the paper).

use super::eig::{jacobi_eigh, pinv_apply};
use super::linalg::{matvec, matvec_t, row_dots};
use super::sinkhorn::plan_f64;

pub struct DenseHessian {
    pub n: usize,
    pub m: usize,
    pub d: usize,
    pub eps: f64,
    x: Vec<f64>,
    y: Vec<f64>,
    /// dense plan (n x m)
    p: Vec<f64>,
    /// induced marginals
    pub ahat: Vec<f64>,
    pub bhat: Vec<f64>,
    /// cached P Y (n x d)
    py: Vec<f64>,
    /// eigendecomposition of the sensitivity matrix H ((n+m)^2)
    eig_w: Vec<f64>,
    eig_v: Vec<f64>,
}

impl DenseHessian {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        x: &[f64],
        y: &[f64],
        a: &[f64],
        b: &[f64],
        fhat: &[f64],
        ghat: &[f64],
        n: usize,
        m: usize,
        d: usize,
        eps: f64,
    ) -> Self {
        let p = plan_f64(x, y, a, b, fhat, ghat, n, m, d, eps);
        let ahat: Vec<f64> = (0..n).map(|i| p[i * m..(i + 1) * m].iter().sum()).collect();
        let bhat: Vec<f64> = (0..m).map(|j| (0..n).map(|i| p[i * m + j]).sum()).collect();
        let py = super::linalg::matmul(&p, y, n, m, d);
        // H = [[diag(ahat), P], [P^T, diag(bhat)]]
        let nm = n + m;
        let mut h = vec![0.0; nm * nm];
        for i in 0..n {
            h[i * nm + i] = ahat[i];
            for j in 0..m {
                h[i * nm + n + j] = p[i * m + j];
                h[(n + j) * nm + i] = p[i * m + j];
            }
        }
        for j in 0..m {
            h[(n + j) * nm + (n + j)] = bhat[j];
        }
        let (eig_w, eig_v) = jacobi_eigh(&h, nm, 60);
        Self { n, m, d, eps, x: x.to_vec(), y: y.to_vec(), p, ahat, bhat, py, eig_w, eig_v }
    }

    /// R A contraction (eq. 29): r1 = 2(ahat.u - u_P), r2 = 2(P^T u - <P^T A, Y>).
    fn r_contract(&self, a_mat: &[f64]) -> (Vec<f64>, Vec<f64>) {
        let (n, m, d) = (self.n, self.m, self.d);
        let u = row_dots(&self.x, a_mat, n, d);
        let u_p = row_dots(&self.py, a_mat, n, d);
        let r1: Vec<f64> = (0..n).map(|i| 2.0 * (self.ahat[i] * u[i] - u_p[i])).collect();
        let ptu = matvec_t(&self.p, &u, n, m);
        let pta = {
            // P^T A: (m x d)
            let mut out = vec![0.0; m * d];
            for i in 0..n {
                for j in 0..m {
                    let pij = self.p[i * m + j];
                    if pij == 0.0 {
                        continue;
                    }
                    for t in 0..d {
                        out[j * d + t] += pij * a_mat[i * d + t];
                    }
                }
            }
            out
        };
        let pta_y = row_dots(&pta, &self.y, m, d);
        let r2: Vec<f64> = (0..m).map(|j| 2.0 * (ptu[j] - pta_y[j])).collect();
        (r1, r2)
    }

    /// The explicit block-diagonal term E A (eq. 27-28).
    fn explicit(&self, a_mat: &[f64]) -> Vec<f64> {
        let (n, m, d, eps) = (self.n, self.m, self.d, self.eps);
        let u = row_dots(&self.x, a_mat, n, d);
        let u_p = row_dots(&self.py, a_mat, n, d);
        // B5 = (P . (A Y^T)) Y
        let mut b5 = vec![0.0; n * d];
        for i in 0..n {
            let ai = &a_mat[i * d..(i + 1) * d];
            for j in 0..m {
                let pij = self.p[i * m + j];
                if pij == 0.0 {
                    continue;
                }
                let yj = &self.y[j * d..(j + 1) * d];
                let w: f64 = ai.iter().zip(yj).map(|(p, q)| p * q).sum();
                for t in 0..d {
                    b5[i * d + t] += pij * w * yj[t];
                }
            }
        }
        let mut out = vec![0.0; n * d];
        for i in 0..n {
            for t in 0..d {
                let b1 = 2.0 * self.ahat[i] * a_mat[i * d + t];
                let b2 = self.ahat[i] * u[i] * self.x[i * d + t];
                let b3 = u[i] * self.py[i * d + t];
                let b4 = u_p[i] * self.x[i * d + t];
                out[i * d + t] = b1 - (4.0 / eps) * (b2 - b3 - b4 + b5[i * d + t]);
            }
        }
        out
    }

    /// Full HVP T A via Moore-Penrose (ground truth).
    pub fn hvp(&self, a_mat: &[f64]) -> Vec<f64> {
        let (n, m, d, eps) = (self.n, self.m, self.d, self.eps);
        let (r1, r2) = self.r_contract(a_mat);
        let mut r = r1.clone();
        r.extend_from_slice(&r2);
        let w = pinv_apply(&self.eig_w, &self.eig_v, &r, n + m, 1e-10);
        let (w1, w2) = w.split_at(n);
        // R^T w (eq. 31)
        let pw2 = matvec(&self.p, w2, n, m);
        // P (diag(w2) Y)
        let mut pv2 = vec![0.0; n * d];
        for i in 0..n {
            for j in 0..m {
                let scale = self.p[i * m + j] * w2[j];
                if scale == 0.0 {
                    continue;
                }
                for t in 0..d {
                    pv2[i * d + t] += scale * self.y[j * d + t];
                }
            }
        }
        let expl = self.explicit(a_mat);
        let mut out = vec![0.0; n * d];
        for i in 0..n {
            for t in 0..d {
                let rt_w = 2.0
                    * (self.ahat[i] * w1[i] * self.x[i * d + t] - w1[i] * self.py[i * d + t]
                        + pw2[i] * self.x[i * d + t]
                        - pv2[i * d + t]);
                out[i * d + t] = rt_w / eps + expl[i * d + t];
            }
        }
        out
    }

    /// Dense damped Schur matvec (for validating the streaming CG operator).
    pub fn schur_matvec(&self, w2: &[f64], tau: f64) -> Vec<f64> {
        let (n, m) = (self.n, self.m);
        let pw = matvec(&self.p, w2, n, m);
        let t: Vec<f64> = (0..n)
            .map(|i| if self.ahat[i] > 0.0 { pw[i] / self.ahat[i] } else { 0.0 })
            .collect();
        let ptt = matvec_t(&self.p, &t, n, m);
        (0..m).map(|j| (self.bhat[j] + tau) * w2[j] - ptt[j]).collect()
    }

    pub fn plan(&self) -> &[f64] {
        &self.p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::clouds::{random_simplex, uniform_cloud};
    use crate::dense::linalg::to_f64;
    use crate::dense::sinkhorn::sinkhorn_f64;

    fn setup(n: usize, d: usize, eps: f64) -> (DenseHessian, Vec<f64>) {
        let x = to_f64(&uniform_cloud(n, d, 11));
        let y = to_f64(&uniform_cloud(n, d, 12));
        let a = to_f64(&random_simplex(n, 13));
        let b = to_f64(&random_simplex(n, 14));
        let sol = sinkhorn_f64(&x, &y, &a, &b, n, n, d, eps, 3000, 1e-13);
        let h = DenseHessian::new(&x, &y, &a, &b, &sol.fhat, &sol.ghat, n, n, d, eps);
        let mut rng = crate::data::rng::Rng::new(15);
        let a_mat: Vec<f64> = (0..n * d).map(|_| rng.normal()).collect();
        (h, a_mat)
    }

    #[test]
    fn hessian_is_symmetric_operator() {
        // <T A, B> == <A, T B> for the dense contraction.
        let (h, a_mat) = setup(12, 3, 0.3);
        let mut rng = crate::data::rng::Rng::new(16);
        let b_mat: Vec<f64> = (0..12 * 3).map(|_| rng.normal()).collect();
        let ta = h.hvp(&a_mat);
        let tb = h.hvp(&b_mat);
        let lhs: f64 = ta.iter().zip(&b_mat).map(|(u, v)| u * v).sum();
        let rhs: f64 = tb.iter().zip(&a_mat).map(|(u, v)| u * v).sum();
        assert!((lhs - rhs).abs() < 1e-6 * lhs.abs().max(1.0), "{lhs} vs {rhs}");
    }

    #[test]
    fn hvp_matches_finite_difference_of_gradient() {
        // grad(X) = 2(diag(r) X - P Y); directional derivative vs T A.
        let n = 10;
        let d = 2;
        let eps = 0.4;
        let x = to_f64(&uniform_cloud(n, d, 21));
        let y = to_f64(&uniform_cloud(n, d, 22));
        let a = vec![1.0 / n as f64; n];
        let b = vec![1.0 / n as f64; n];
        let grad_at = |xs: &[f64]| -> Vec<f64> {
            let sol = sinkhorn_f64(xs, &y, &a, &b, n, n, d, eps, 5000, 1e-14);
            let p = plan_f64(xs, &y, &a, &b, &sol.fhat, &sol.ghat, n, n, d, eps);
            let py = crate::dense::linalg::matmul(&p, &y, n, n, d);
            let r: Vec<f64> = (0..n).map(|i| p[i * n..(i + 1) * n].iter().sum()).collect();
            (0..n * d)
                .map(|k| 2.0 * (r[k / d] * xs[k] - py[k]))
                .collect()
        };
        let sol = sinkhorn_f64(&x, &y, &a, &b, n, n, d, eps, 5000, 1e-14);
        let h = DenseHessian::new(&x, &y, &a, &b, &sol.fhat, &sol.ghat, n, n, d, eps);
        let mut rng = crate::data::rng::Rng::new(23);
        let dir: Vec<f64> = (0..n * d).map(|_| rng.normal()).collect();
        let t_dir = h.hvp(&dir);
        let step = 1e-5;
        let xp: Vec<f64> = x.iter().zip(&dir).map(|(u, v)| u + step * v).collect();
        let xm: Vec<f64> = x.iter().zip(&dir).map(|(u, v)| u - step * v).collect();
        let gp = grad_at(&xp);
        let gm = grad_at(&xm);
        let fd: Vec<f64> = gp.iter().zip(&gm).map(|(u, v)| (u - v) / (2.0 * step)).collect();
        let num: f64 = t_dir.iter().zip(&fd).map(|(u, v)| (u - v) * (u - v)).sum::<f64>().sqrt();
        let den: f64 = fd.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-12);
        assert!(num / den < 2e-3, "relative FD mismatch {}", num / den);
    }

    #[test]
    fn schur_nullspace_is_ones() {
        // S 1_m = 0 at converged potentials (section F.2).
        let (h, _) = setup(14, 3, 0.3);
        let ones = vec![1.0; h.m];
        let s1 = h.schur_matvec(&ones, 0.0);
        let norm: f64 = s1.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!(norm < 1e-8, "|S 1| = {norm}");
    }
}
