//! Minimal dense f64 linear algebra on flat row-major slices.

/// out = A (r x k) * B (k x c), row-major.
pub fn matmul(a: &[f64], b: &[f64], r: usize, k: usize, c: usize) -> Vec<f64> {
    let mut out = vec![0.0; r * c];
    for i in 0..r {
        for l in 0..k {
            let av = a[i * k + l];
            if av == 0.0 {
                continue;
            }
            let brow = &b[l * c..(l + 1) * c];
            let orow = &mut out[i * c..(i + 1) * c];
            for j in 0..c {
                orow[j] += av * brow[j];
            }
        }
    }
    out
}

/// y = A (r x c) * x (c).
pub fn matvec(a: &[f64], x: &[f64], r: usize, c: usize) -> Vec<f64> {
    (0..r)
        .map(|i| a[i * c..(i + 1) * c].iter().zip(x).map(|(u, v)| u * v).sum())
        .collect()
}

/// y = A^T (r x c) * x (r) -> (c).
pub fn matvec_t(a: &[f64], x: &[f64], r: usize, c: usize) -> Vec<f64> {
    let mut out = vec![0.0; c];
    for i in 0..r {
        let xi = x[i];
        if xi == 0.0 {
            continue;
        }
        for j in 0..c {
            out[j] += a[i * c + j] * xi;
        }
    }
    out
}

pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(u, v)| u * v).sum()
}

pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Row-wise dot products of two (n x d) matrices -> (n).
pub fn row_dots(a: &[f64], b: &[f64], n: usize, d: usize) -> Vec<f64> {
    (0..n)
        .map(|i| dot(&a[i * d..(i + 1) * d], &b[i * d..(i + 1) * d]))
        .collect()
}

pub fn to_f64(v: &[f32]) -> Vec<f64> {
    v.iter().map(|&x| x as f64).collect()
}

pub fn to_f32(v: &[f64]) -> Vec<f32> {
    v.iter().map(|&x| x as f32).collect()
}

/// log-sum-exp of a slice (stable).
pub fn lse(xs: &[f64]) -> f64 {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if m == f64::NEG_INFINITY {
        return f64::NEG_INFINITY;
    }
    m + xs.iter().map(|x| (x - m).exp()).sum::<f64>().ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = vec![1., 2., 3., 4.];
        let id = vec![1., 0., 0., 1.];
        assert_eq!(matmul(&a, &id, 2, 2, 2), a);
    }

    #[test]
    fn matvec_vs_matmul() {
        let a = vec![1., 2., 3., 4., 5., 6.]; // 2x3
        let x = vec![1., 1., 2.];
        assert_eq!(matvec(&a, &x, 2, 3), vec![9., 21.]);
        assert_eq!(matvec_t(&a, &[1., 1.], 2, 3), vec![5., 7., 9.]);
    }

    #[test]
    fn lse_stable() {
        assert!((lse(&[1000.0, 1000.0]) - (1000.0 + 2f64.ln())).abs() < 1e-9);
        assert_eq!(lse(&[f64::NEG_INFINITY; 3]), f64::NEG_INFINITY);
    }
}
