//! Pure-Rust f64 dense reference: log-domain Sinkhorn, the dense transport
//! plan, the full data-space Hessian contraction with a Moore-Penrose
//! pseudoinverse, and the Jacobi eigensolver backing it.
//!
//! This is (a) the ground truth for the paper's parity tables (Table 14,
//! 20, 22) and (b) the fp64 "materialized" execution plan the fp32 flash
//! kernels are measured against.  Nothing here touches PJRT.

pub mod eig;
pub mod hessian;
pub mod linalg;
pub mod sinkhorn;

pub use hessian::DenseHessian;
pub use sinkhorn::DenseSolution;
