//! Dense f64 log-domain Sinkhorn (the fp64 reference of Tables 20-22).

use super::linalg::lse;

/// Converged dense solution in shifted potentials.
#[derive(Debug, Clone)]
pub struct DenseSolution {
    pub fhat: Vec<f64>,
    pub ghat: Vec<f64>,
    pub iters: usize,
    pub final_delta: f64,
}

fn safe_ln(w: f64) -> f64 {
    if w > 0.0 {
        w.ln()
    } else {
        f64::NEG_INFINITY
    }
}

/// One dense f-update (eq. 10): fhat_i = -eps lse_j(2 x_i.y_j/eps + ghat_j/eps + ln b_j).
fn f_update(
    x: &[f64],
    y: &[f64],
    ghat: &[f64],
    b: &[f64],
    n: usize,
    m: usize,
    d: usize,
    eps: f64,
    out: &mut Vec<f64>,
    scratch: &mut Vec<f64>,
) {
    out.clear();
    for i in 0..n {
        scratch.clear();
        let xi = &x[i * d..(i + 1) * d];
        for j in 0..m {
            let yj = &y[j * d..(j + 1) * d];
            let dotv: f64 = xi.iter().zip(yj).map(|(u, v)| u * v).sum();
            scratch.push((2.0 * dotv + ghat[j]) / eps + safe_ln(b[j]));
        }
        out.push(-eps * lse(scratch));
    }
}

/// Dense alternating Sinkhorn to `iters` iterations (or delta < tol).
pub fn sinkhorn_f64(
    x: &[f64],
    y: &[f64],
    a: &[f64],
    b: &[f64],
    n: usize,
    m: usize,
    d: usize,
    eps: f64,
    iters: usize,
    tol: f64,
) -> DenseSolution {
    let mut fhat: Vec<f64> = (0..n)
        .map(|i| -x[i * d..(i + 1) * d].iter().map(|v| v * v).sum::<f64>())
        .collect();
    let mut ghat: Vec<f64> = (0..m)
        .map(|j| -y[j * d..(j + 1) * d].iter().map(|v| v * v).sum::<f64>())
        .collect();
    let mut f_new = Vec::with_capacity(n);
    let mut g_new = Vec::with_capacity(m);
    let mut scratch = Vec::with_capacity(n.max(m));
    let mut delta = f64::INFINITY;
    let mut done = 0;
    for _ in 0..iters {
        f_update(x, y, &ghat, b, n, m, d, eps, &mut f_new, &mut scratch);
        f_update(y, x, &f_new, a, m, n, d, eps, &mut g_new, &mut scratch);
        delta = f_new
            .iter()
            .zip(&fhat)
            .chain(g_new.iter().zip(&ghat))
            .map(|(u, v)| (u - v).abs())
            .fold(0.0, f64::max);
        std::mem::swap(&mut fhat, &mut f_new);
        std::mem::swap(&mut ghat, &mut g_new);
        done += 1;
        if delta < tol {
            break;
        }
    }
    DenseSolution { fhat, ghat, iters: done, final_delta: delta }
}

/// Dense transport plan P from potentials (eq. 12).
pub fn plan_f64(
    x: &[f64],
    y: &[f64],
    a: &[f64],
    b: &[f64],
    fhat: &[f64],
    ghat: &[f64],
    n: usize,
    m: usize,
    d: usize,
    eps: f64,
) -> Vec<f64> {
    let mut p = vec![0.0; n * m];
    for i in 0..n {
        let xi = &x[i * d..(i + 1) * d];
        for j in 0..m {
            let yj = &y[j * d..(j + 1) * d];
            let dotv: f64 = xi.iter().zip(yj).map(|(u, v)| u * v).sum();
            let logp = safe_ln(a[i]) + safe_ln(b[j]) + (fhat[i] + ghat[j] + 2.0 * dotv) / eps;
            p[i * m + j] = logp.exp();
        }
    }
    p
}

/// Dual objective in f64 (for Table 20's fp32-vs-fp64 comparison).
pub fn dual_cost_f64(
    x: &[f64],
    y: &[f64],
    a: &[f64],
    b: &[f64],
    fhat: &[f64],
    ghat: &[f64],
    n: usize,
    m: usize,
    d: usize,
) -> f64 {
    let mut acc = 0.0;
    for i in 0..n {
        let alpha: f64 = x[i * d..(i + 1) * d].iter().map(|v| v * v).sum();
        acc += a[i] * (fhat[i] + alpha);
    }
    for j in 0..m {
        let beta: f64 = y[j * d..(j + 1) * d].iter().map(|v| v * v).sum();
        acc += b[j] * (ghat[j] + beta);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::clouds::uniform_cloud;
    use crate::dense::linalg::to_f64;

    #[test]
    fn converged_plan_has_prescribed_marginals() {
        let (n, m, d) = (24, 30, 3);
        let x = to_f64(&uniform_cloud(n, d, 1));
        let y = to_f64(&uniform_cloud(m, d, 2));
        let a = vec![1.0 / n as f64; n];
        let b = vec![1.0 / m as f64; m];
        let sol = sinkhorn_f64(&x, &y, &a, &b, n, m, d, 0.1, 2000, 1e-12);
        let p = plan_f64(&x, &y, &a, &b, &sol.fhat, &sol.ghat, n, m, d, 0.1);
        for i in 0..n {
            let r: f64 = p[i * m..(i + 1) * m].iter().sum();
            assert!((r - a[i]).abs() < 1e-8, "row {i}: {r}");
        }
        for j in 0..m {
            let c: f64 = (0..n).map(|i| p[i * m + j]).sum();
            assert!((c - b[j]).abs() < 1e-8, "col {j}: {c}");
        }
    }

    #[test]
    fn more_iterations_never_hurt() {
        let (n, d) = (16, 2);
        let x = to_f64(&uniform_cloud(n, d, 3));
        let y = to_f64(&uniform_cloud(n, d, 4));
        let a = vec![1.0 / n as f64; n];
        let s1 = sinkhorn_f64(&x, &y, &a, &a, n, n, d, 0.2, 10, 0.0);
        let s2 = sinkhorn_f64(&x, &y, &a, &a, n, n, d, 0.2, 100, 0.0);
        assert!(s2.final_delta <= s1.final_delta);
    }
}
