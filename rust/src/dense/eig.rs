//! Cyclic Jacobi eigensolver for symmetric matrices -- backs the dense
//! Moore-Penrose pseudoinverse used as HVP ground truth (paper section
//! H.2.3: "eigendecomposition-based pseudoinverse, threshold 1e-10").

/// Eigendecomposition A = V diag(w) V^T of a symmetric n x n matrix.
/// Returns (eigenvalues, eigenvectors-as-columns flat row-major n x n).
pub fn jacobi_eigh(a_in: &[f64], n: usize, max_sweeps: usize) -> (Vec<f64>, Vec<f64>) {
    let mut a = a_in.to_vec();
    // v starts as identity; columns become eigenvectors.
    let mut v = vec![0.0; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let off = |a: &[f64]| -> f64 {
        let mut s = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                s += a[i * n + j] * a[i * n + j];
            }
        }
        s
    };
    let scale: f64 = a_in.iter().map(|x| x * x).sum::<f64>().max(1e-300);
    for _ in 0..max_sweeps {
        if off(&a) <= 1e-26 * scale {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a[p * n + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = a[p * n + p];
                let aqq = a[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p, q of A
                for k in 0..n {
                    let akp = a[k * n + p];
                    let akq = a[k * n + q];
                    a[k * n + p] = c * akp - s * akq;
                    a[k * n + q] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[p * n + k];
                    let aqk = a[q * n + k];
                    a[p * n + k] = c * apk - s * aqk;
                    a[q * n + k] = s * apk + c * aqk;
                }
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }
    let w: Vec<f64> = (0..n).map(|i| a[i * n + i]).collect();
    (w, v)
}

/// Apply the Moore-Penrose pseudoinverse of a symmetric matrix to a vector:
/// A^+ x = V diag(1/w where |w| > thresh) V^T x.
pub fn pinv_apply(w: &[f64], v: &[f64], x: &[f64], n: usize, thresh: f64) -> Vec<f64> {
    // coeffs = V^T x
    let mut coeff = vec![0.0; n];
    for k in 0..n {
        let mut s = 0.0;
        for i in 0..n {
            s += v[i * n + k] * x[i];
        }
        coeff[k] = s;
    }
    let wmax = w.iter().cloned().fold(0.0f64, |acc, x| acc.max(x.abs()));
    for k in 0..n {
        coeff[k] = if w[k].abs() > thresh * wmax.max(1.0) {
            coeff[k] / w[k]
        } else {
            0.0
        };
    }
    // out = V coeff
    let mut out = vec![0.0; n];
    for i in 0..n {
        let mut s = 0.0;
        for k in 0..n {
            s += v[i * n + k] * coeff[k];
        }
        out[i] = s;
    }
    out
}

/// Smallest eigenvalue of a symmetric matrix (for Lanczos validation).
pub fn min_eig(a: &[f64], n: usize) -> f64 {
    let (w, _) = jacobi_eigh(a, n, 40);
    w.into_iter().fold(f64::INFINITY, f64::min)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagonal_matrix_eigs() {
        let a = vec![3.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, -2.0];
        let (mut w, _) = jacobi_eigh(&a, 3, 30);
        w.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert!((w[0] + 2.0).abs() < 1e-12);
        assert!((w[1] - 1.0).abs() < 1e-12);
        assert!((w[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] -> eigs 1, 3
        let (mut w, v) = jacobi_eigh(&[2.0, 1.0, 1.0, 2.0], 2, 30);
        w.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert!((w[0] - 1.0).abs() < 1e-12 && (w[1] - 3.0).abs() < 1e-12);
        // reconstruct: A v_k = w_k v_k
        let a = [2.0, 1.0, 1.0, 2.0];
        for k in 0..2 {
            let vk = [v[k], v[2 + k]];
            let av = [a[0] * vk[0] + a[1] * vk[1], a[2] * vk[0] + a[3] * vk[1]];
            let lam = (av[0] * vk[0] + av[1] * vk[1]) / (vk[0] * vk[0] + vk[1] * vk[1]);
            let r = ((av[0] - lam * vk[0]).powi(2) + (av[1] - lam * vk[1]).powi(2)).sqrt();
            assert!(r < 1e-10);
        }
    }

    #[test]
    fn pinv_of_singular_matrix() {
        // rank-1: [[1,1],[1,1]] has eigs {0, 2}; A^+ b solves least squares.
        let a = [1.0, 1.0, 1.0, 1.0];
        let (w, v) = jacobi_eigh(&a, 2, 30);
        let x = pinv_apply(&w, &v, &[2.0, 2.0], 2, 1e-10);
        // A^+ [2,2] = [1,1]/... A [1,1]^T/2 scaled: A^+ = A/4 -> [1,1]
        assert!((x[0] - 1.0).abs() < 1e-10 && (x[1] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn random_spd_reconstruction() {
        let n = 12;
        let mut rng = crate::data::rng::Rng::new(5);
        let mut b = vec![0.0; n * n];
        for v in &mut b {
            *v = rng.normal();
        }
        // A = B B^T is SPD
        let a = crate::dense::linalg::matmul(
            &b,
            &{
                let mut bt = vec![0.0; n * n];
                for i in 0..n {
                    for j in 0..n {
                        bt[j * n + i] = b[i * n + j];
                    }
                }
                bt
            },
            n,
            n,
            n,
        );
        let (w, _) = jacobi_eigh(&a, n, 40);
        assert!(w.iter().all(|&x| x > -1e-9));
        let trace: f64 = (0..n).map(|i| a[i * n + i]).sum();
        assert!((w.iter().sum::<f64>() - trace).abs() < 1e-8 * trace.abs().max(1.0));
    }
}
