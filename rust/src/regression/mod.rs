//! OT-based shuffled linear regression (paper section 4.2 / H.4).
//!
//! Given (X, Y~) with Y~ = Pi*(X W* + E) for an unknown permutation Pi*,
//! estimate W* by minimizing L(W) = OT_eps(mu_{XW}, nu_{Y~}).  The
//! parameter gradient and Hessian-vector products chain through the
//! data-space quantities: grad_W = X^T grad_Y OT, H_W v = X^T T (X v).

pub mod saddle;

use anyhow::Result;

use crate::coordinator::router::Router;
use crate::data::cytometry::{cytometry_cloud, NUM_MARKERS};
use crate::data::rng::Rng;
use crate::hvp::oracle::HvpOracle;
use crate::ot::problem::OtProblem;
use crate::ot::solver::{Potentials, SinkhornSolver, SolverConfig};
use crate::ot::Transport;
use crate::runtime::ComputeBackend;

pub use saddle::{run_saddle_escape, Phase, SaddleConfig, TrajectoryPoint};

/// The workload: source features X and shuffled observations Y~.
#[derive(Clone)]
pub struct ShuffledRegression {
    /// n x d features.
    pub x: Vec<f32>,
    /// n x d shuffled targets.
    pub y_obs: Vec<f32>,
    pub n: usize,
    pub d: usize,
    pub eps: f32,
}

impl ShuffledRegression {
    /// Synthetic instance on cytometry-like data (paper section H.4):
    /// W*_ij ~ N(0, 1/d), Y = X W* + noise, then an unknown permutation.
    /// Returns (workload, ground-truth W*).
    pub fn synthetic(n: usize, eps: f32, noise: f32, seed: u64) -> (Self, Vec<f32>) {
        let d = NUM_MARKERS;
        let x = cytometry_cloud(n, seed);
        let mut rng = Rng::new(seed.wrapping_add(1));
        let w_star: Vec<f32> = (0..d * d)
            .map(|_| (rng.normal() / (d as f64).sqrt()) as f32)
            .collect();
        let mut y = matmul_xw(&x, &w_star, n, d);
        // estimate target std for noise scaling
        let std = {
            let mean: f64 = y.iter().map(|&v| v as f64).sum::<f64>() / y.len() as f64;
            (y.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / y.len() as f64).sqrt()
        };
        for v in &mut y {
            *v += (noise as f64 * std * rng.normal()) as f32;
        }
        // unknown permutation
        let perm = rng.permutation(n);
        let mut y_obs = vec![0.0f32; n * d];
        for (i, &pi) in perm.iter().enumerate() {
            y_obs[i * d..(i + 1) * d].copy_from_slice(&y[pi * d..(pi + 1) * d]);
        }
        (Self { x, y_obs, n, d, eps }, w_star)
    }

    /// The OT problem at parameter W: mu = points X W, nu = Y~.
    pub fn problem_at(&self, w: &[f32]) -> Result<OtProblem> {
        let y = matmul_xw(&self.x, w, self.n, self.d);
        OtProblem::uniform(y, self.y_obs.clone(), self.n, self.n, self.d, self.eps)
    }

    /// Loss L(W) and gradient dL/dW (d x d), plus the solved potentials
    /// (reused to build the HVP oracle at this iterate).
    pub fn loss_grad(
        &self,
        backend: &dyn ComputeBackend,
        cfg: &SolverConfig,
        w: &[f32],
    ) -> Result<(f64, Vec<f32>, OtProblem, Potentials)> {
        let solver = SinkhornSolver::new(backend, cfg.clone());
        let prob = self.problem_at(w)?;
        let (pot, report) = solver.solve(&prob)?;
        let t = Transport::new(backend, solver.router(), &prob, &pot)?;
        let (grad_y, _) = t.grad_x()?;
        let grad_w = xt_g(&self.x, &grad_y, self.n, self.d);
        Ok((report.cost, grad_w, prob, pot))
    }

    /// Loss only (Armijo line-search evaluations).
    pub fn loss(&self, backend: &dyn ComputeBackend, cfg: &SolverConfig, w: &[f32]) -> Result<f64> {
        let solver = SinkhornSolver::new(backend, cfg.clone());
        let prob = self.problem_at(w)?;
        let (_, report) = solver.solve(&prob)?;
        Ok(report.cost)
    }

    /// Parameter-space HVP: H_W v = X^T T (X v), with T the data-space
    /// Hessian oracle at the current iterate (Thm. 5).
    pub fn hvp_w(&self, oracle: &HvpOracle, v: &[f32]) -> Result<Vec<f32>> {
        let a_mat = matmul_xw(&self.x, v, self.n, self.d); // X v  (n x d)
        let (g, _) = oracle.hvp(&a_mat)?;
        Ok(xt_g(&self.x, &g, self.n, self.d)) // X^T G  (d x d)
    }

    /// Build the curvature oracle at a solved iterate.
    pub fn oracle<'e>(
        &self,
        backend: &'e dyn ComputeBackend,
        router: &Router,
        prob: &OtProblem,
        pot: &Potentials,
        tau: f32,
        eta: f64,
        max_cg: usize,
    ) -> Result<HvpOracle<'e>> {
        HvpOracle::new(backend, router, prob, pot, tau, eta, max_cg)
    }

    /// Parameter error |W - W*|_F / |W*|_F.
    pub fn rel_param_error(w: &[f32], w_star: &[f32]) -> f64 {
        let num: f64 = w
            .iter()
            .zip(w_star)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let den: f64 = w_star.iter().map(|&b| (b as f64).powi(2)).sum::<f64>().sqrt();
        num / den.max(1e-12)
    }
}

/// Y = X W for X (n x d), W (d x d).
pub fn matmul_xw(x: &[f32], w: &[f32], n: usize, d: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; n * d];
    for i in 0..n {
        for k in 0..d {
            let xv = x[i * d + k];
            if xv == 0.0 {
                continue;
            }
            for j in 0..d {
                y[i * d + j] += xv * w[k * d + j];
            }
        }
    }
    y
}

/// G_W = X^T G for X (n x d), G (n x d) -> (d x d).
pub fn xt_g(x: &[f32], g: &[f32], n: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; d * d];
    for i in 0..n {
        for k in 0..d {
            let xv = x[i * d + k];
            if xv == 0.0 {
                continue;
            }
            for j in 0..d {
                out[k * d + j] += xv * g[i * d + j];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_shapes_and_determinism() {
        let (w1, ws1) = ShuffledRegression::synthetic(64, 0.1, 0.05, 7);
        let (w2, ws2) = ShuffledRegression::synthetic(64, 0.1, 0.05, 7);
        assert_eq!(w1.x, w2.x);
        assert_eq!(ws1, ws2);
        assert_eq!(w1.y_obs.len(), 64 * NUM_MARKERS);
    }

    #[test]
    fn matmul_chain_identities() {
        // X I = X; X^T (X v) is symmetric quadratic form
        let n = 10;
        let d = 3;
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let mut eye = vec![0.0f32; d * d];
        for i in 0..d {
            eye[i * d + i] = 1.0;
        }
        assert_eq!(matmul_xw(&x, &eye, n, d), x);
        let v: Vec<f32> = (0..d * d).map(|_| rng.normal() as f32).collect();
        let xv = matmul_xw(&x, &v, n, d);
        let q = xt_g(&x, &xv, n, d); // X^T X v: contract with u gives symmetric form
        let u: Vec<f32> = (0..d * d).map(|_| rng.normal() as f32).collect();
        let xu = matmul_xw(&x, &u, n, d);
        let q2 = xt_g(&x, &xu, n, d);
        let lhs: f64 = q.iter().zip(&u).map(|(&a, &b)| a as f64 * b as f64).sum();
        let rhs: f64 = q2.iter().zip(&v).map(|(&a, &b)| a as f64 * b as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0));
    }

    #[test]
    fn shuffled_targets_are_a_permutation_of_clean() {
        let (wl, w_star) = ShuffledRegression::synthetic(32, 0.1, 0.0, 9);
        let clean = matmul_xw(&wl.x, &w_star, wl.n, wl.d);
        // multiset of rows must match: compare sorted row checksums
        let sum_rows = |m: &[f32]| {
            let mut v: Vec<i64> = m
                .chunks(wl.d)
                .map(|r| r.iter().map(|&x| (x * 1e4) as i64).sum())
                .collect();
            v.sort_unstable();
            v
        };
        assert_eq!(sum_rows(&clean), sum_rows(&wl.y_obs));
    }
}
