//! Saddle-escape detection and the Adam -> Newton switching rule (paper
//! section 4.2 / H.4 and Figure 5/8): monitor lambda_min(H_W) via Lanczos
//! every few steps; full-batch Adam while lambda_min < threshold, Newton-CG
//! once locally convex, with automatic fallback on re-entry into a saddle
//! region (the multi-saddle trajectory of Figure 8).

use std::time::Instant;

use anyhow::Result;

use crate::hvp::lanczos::lanczos_min_eig;
use crate::optim::adam::Adam;
use crate::optim::newton::armijo_newton_step;
use crate::ot::solver::{SinkhornSolver, SolverConfig};
use crate::runtime::ComputeBackend;

use super::ShuffledRegression;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Adam,
    Newton,
}

#[derive(Debug, Clone)]
pub struct SaddleConfig {
    pub adam_lr: f32,
    /// switch threshold on lambda_min (paper: 0.001).
    pub lambda_switch: f64,
    /// Lanczos check cadence in steps (paper: every 5).
    pub check_every: usize,
    pub max_steps: usize,
    /// stop when |grad| below this (paper: 5e-3).
    pub grad_tol: f64,
    /// Newton knobs (paper H.4).
    pub newton_step0: f64,
    pub newton_backtrack: f64,
    pub newton_c: f64,
    pub cg_tau: f32,
    pub cg_eta: f64,
    pub cg_max: usize,
    pub lanczos_k: usize,
}

impl Default for SaddleConfig {
    fn default() -> Self {
        Self {
            adam_lr: 0.03,
            lambda_switch: 1e-3,
            check_every: 5,
            max_steps: 300,
            grad_tol: 5e-3,
            newton_step0: 10.0,
            newton_backtrack: 0.5,
            newton_c: 0.1,
            cg_tau: 1e-5,
            cg_eta: 1e-6,
            cg_max: 100,
            lanczos_k: 20,
        }
    }
}

#[derive(Debug, Clone)]
pub struct TrajectoryPoint {
    pub step: usize,
    pub loss: f64,
    pub grad_norm: f64,
    pub lambda_min: Option<f64>,
    pub phase: Phase,
    pub wall_s: f64,
}

#[derive(Debug, Clone)]
pub struct SaddleReport {
    pub w: Vec<f32>,
    pub trajectory: Vec<TrajectoryPoint>,
    pub escapes: usize,
    pub reentries: usize,
    pub newton_steps: usize,
    pub adam_steps: usize,
    pub converged: bool,
}

/// Run the full controller from `w0`.
pub fn run_saddle_escape(
    backend: &dyn ComputeBackend,
    workload: &ShuffledRegression,
    solver_cfg: &SolverConfig,
    w0: &[f32],
    cfg: &SaddleConfig,
) -> Result<SaddleReport> {
    let d2 = workload.d * workload.d;
    assert_eq!(w0.len(), d2);
    let t0 = Instant::now();
    let mut w = w0.to_vec();
    let mut adam = Adam::new(d2, cfg.adam_lr);
    let mut phase = Phase::Adam;
    let mut trajectory = Vec::new();
    let (mut escapes, mut reentries, mut newton_steps, mut adam_steps) = (0, 0, 0, 0);
    let mut converged = false;
    let solver = SinkhornSolver::new(backend, solver_cfg.clone());

    for step in 0..cfg.max_steps {
        let (loss, grad, prob, pot) = workload.loss_grad(backend, solver_cfg, &w)?;
        let grad_norm = grad.iter().map(|&g| (g as f64).powi(2)).sum::<f64>().sqrt();

        // periodic curvature check (and always while in Newton phase)
        let lambda_min = if step % cfg.check_every == 0 || phase == Phase::Newton {
            let oracle = workload.oracle(
                backend,
                solver.router(),
                &prob,
                &pot,
                cfg.cg_tau,
                cfg.cg_eta,
                cfg.cg_max,
            )?;
            let rep = lanczos_min_eig(
                |v: &[f32]| workload.hvp_w(&oracle, v),
                d2,
                cfg.lanczos_k,
                42 + step as u64,
            )?;
            Some(rep.lambda_min)
        } else {
            None
        };

        if let Some(lm) = lambda_min {
            match phase {
                Phase::Adam if lm >= cfg.lambda_switch => {
                    phase = Phase::Newton;
                    escapes += 1;
                }
                Phase::Newton if lm < cfg.lambda_switch => {
                    phase = Phase::Adam;
                    adam.reset();
                    reentries += 1;
                }
                _ => {}
            }
        }

        trajectory.push(TrajectoryPoint {
            step,
            loss,
            grad_norm,
            lambda_min,
            phase,
            wall_s: t0.elapsed().as_secs_f64(),
        });

        if grad_norm < cfg.grad_tol {
            converged = true;
            break;
        }

        match phase {
            Phase::Adam => {
                adam.step(&mut w, &grad);
                adam_steps += 1;
            }
            Phase::Newton => {
                let oracle = workload.oracle(
                    engine,
                    solver.router(),
                    &prob,
                    &pot,
                    cfg.cg_tau,
                    cfg.cg_eta,
                    cfg.cg_max,
                )?;
                let out = armijo_newton_step(
                    &w,
                    &grad,
                    loss,
                    |v: &[f32]| workload.hvp_w(&oracle, v),
                    |cand: &[f32]| workload.loss(backend, solver_cfg, cand),
                    cfg.cg_tau,
                    cfg.cg_eta,
                    cfg.cg_max,
                    cfg.newton_step0,
                    cfg.newton_backtrack,
                    cfg.newton_c,
                    25,
                )?;
                if out.accepted {
                    w = out.params;
                    newton_steps += 1;
                } else {
                    // line search failed: curvature is unreliable here
                    phase = Phase::Adam;
                    adam.reset();
                    reentries += 1;
                    adam.step(&mut w, &grad);
                    adam_steps += 1;
                }
            }
        }
    }

    Ok(SaddleReport { w, trajectory, escapes, reentries, newton_steps, adam_steps, converged })
}
