//! The PJRT execution engine: lazy compile + executable cache + call.
//!
//! Compiled only with `--features pjrt`, which additionally requires the
//! `xla` FFI crate and artifacts from `make artifacts` — see README
//! "Backends".  The default (hermetic) build uses
//! [`crate::native::NativeBackend`] instead.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::artifacts::Manifest;
use super::tensor::Tensor;

/// Aggregate counters for the hot path (exposed by `repro serve` metrics
/// and the §Perf profiling pass).
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub calls: u64,
    pub compiles: u64,
    pub cache_hits: u64,
    pub exec_time: Duration,
    pub compile_time: Duration,
}

/// Loads HLO-text artifacts, compiles them once on the PJRT CPU client and
/// executes them.  `!Send` by construction (PJRT handles are raw pointers);
/// the coordinator service gives it a dedicated actor thread.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<EngineStats>,
}

impl Engine {
    pub fn new<P: Into<PathBuf>>(artifact_dir: P) -> Result<Self> {
        let dir = artifact_dir.into();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
        Ok(Self { client, manifest, dir, cache: RefCell::default(), stats: RefCell::default() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> EngineStats {
        self.stats.borrow().clone()
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the executable for an artifact key.
    fn executable(&self, key: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(key) {
            self.stats.borrow_mut().cache_hits += 1;
            return Ok(exe.clone());
        }
        let path = self.manifest.file_path(&self.dir, key)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parsing HLO text {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {key}: {e}"))?,
        );
        let mut stats = self.stats.borrow_mut();
        stats.compiles += 1;
        stats.compile_time += t0.elapsed();
        drop(stats);
        self.cache.borrow_mut().insert(key.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile an artifact (used by the service warmup path).
    pub fn warm(&self, key: &str) -> Result<()> {
        self.executable(key).map(|_| ())
    }

    /// Validate inputs against the manifest entry, execute, unpack outputs.
    pub fn call(&self, key: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let entry = self.manifest.entry(key)?;
        if inputs.len() != entry.inputs.len() {
            bail!(
                "{key}: expected {} inputs, got {}",
                entry.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, spec)) in inputs.iter().zip(&entry.inputs).enumerate() {
            if t.shape() != spec.shape.as_slice() || t.dtype_name() != spec.dtype {
                bail!(
                    "{key}: input {i} ({}) expects {:?} {}, got {:?} {}",
                    spec.name.as_deref().unwrap_or("?"),
                    spec.shape,
                    spec.dtype,
                    t.shape(),
                    t.dtype_name()
                );
            }
        }
        let exe = self.executable(key)?;
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let t0 = Instant::now();
        let bufs = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow::anyhow!("executing {key}: {e}"))?;
        let result = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result of {key}: {e}"))?;
        let mut stats = self.stats.borrow_mut();
        stats.calls += 1;
        stats.exec_time += t0.elapsed();
        drop(stats);
        // aot.py lowers with return_tuple=True: always a tuple, even 1-ary.
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling result of {key}: {e}"))?;
        if parts.len() != entry.outputs.len() {
            bail!(
                "{key}: manifest promises {} outputs, runtime produced {}",
                entry.outputs.len(),
                parts.len()
            );
        }
        parts.iter().map(Tensor::from_literal).collect()
    }

    /// Shorthand: call an op at bucket (n, m, d).
    pub fn call_op(
        &self,
        op: &str,
        n: usize,
        m: usize,
        d: usize,
        inputs: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        self.call(&Manifest::key(op, n, m, d), inputs)
    }
}

impl super::backend::ComputeBackend for Engine {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn k_fused(&self) -> usize {
        self.manifest.k_fused
    }

    fn num_classes(&self) -> Option<usize> {
        Some(self.manifest.num_classes)
    }

    fn router(&self) -> crate::coordinator::router::Router {
        crate::coordinator::router::Router::from_manifest(&self.manifest)
    }

    fn has(&self, key: &str) -> bool {
        self.manifest.has(key)
    }

    fn call(&self, key: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        Engine::call(self, key, inputs)
    }
}
