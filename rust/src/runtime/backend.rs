//! The `ComputeBackend` trait: the seam between the L3 coordinator and
//! whatever evaluates the fused streaming ops.
//!
//! Every heavy op the coordinator issues — Sinkhorn steps (plain, fused
//! k-step, label-augmented), transport applications (`apply_pv*`,
//! `apply_ptu*`, `hadamard_pv`, `apply_plan`), gradients, marginals and the
//! Schur-complement matvec — goes through [`ComputeBackend::call`] with an
//! op key and host [`Tensor`] inputs.  Two implementations exist:
//!
//! * [`crate::native::NativeBackend`] — pure Rust, cache-tiled streaming
//!   LogSumExp over point-cloud tiles (the paper's SRAM-tiling structure on
//!   CPU), with a d-blocked SIMD dot/LSE microkernel and row ranges fanned
//!   out over a persistent process-global worker pool
//!   (`crate::native::pool`, sized by `FLASH_SINKHORN_THREADS`).
//!   Exact-shape routing, no padding, no FFI.  Always available.
//! * `runtime::Engine` (feature `pjrt`) — loads Python-lowered HLO
//!   artifacts through the PJRT C API; static shape buckets + zero-weight
//!   padding.
//!
//! Op keys use the artifact-manifest convention `"{op}__n{n}_m{m}_d{d}"`
//! (see [`super::Manifest::key`]); backends that do not pre-compile per
//! shape (native) ignore the suffix and derive shapes from the inputs.
//! The dual objective itself stays host-side ([`crate::ot::cost`]): it is
//! O(n + m) and never worth a backend round trip.

use anyhow::{anyhow, bail, Result};

use crate::coordinator::router::Router;
use crate::ot::problem::BatchedProblem;

use super::tensor::Tensor;

/// Per-problem outcome of one batched step block
/// ([`ComputeBackend::lse_step_batch`]).
#[derive(Debug, Clone, Default)]
pub struct BatchStepOut {
    /// Sup-norm f change of the final inner iteration (0 when frozen).
    pub df: f32,
    /// Sup-norm g change of the final inner iteration (0 when frozen).
    pub dg: f32,
    /// This problem's share of the batched call's IO/work — exactly what a
    /// sequential solve of the problem would have charged, so per-job
    /// `SolveReport::io` stays exact under batching.
    pub io: crate::obs::IoStats,
}

/// A backend that evaluates fused streaming OT ops on host tensors.
pub trait ComputeBackend {
    /// Short backend identifier ("native", "pjrt", ...).
    fn name(&self) -> &'static str;

    /// Number of inner iterations in the fused `k{k}_*` step ops.
    fn k_fused(&self) -> usize;

    /// Class-count constraint for label (OTDD) ops, if the backend bakes
    /// the class-distance matrix side into its executables.  `None` means
    /// any `v` is accepted (native).
    fn num_classes(&self) -> Option<usize>;

    /// Shape-bucket coverage for the router.  PJRT reports its compiled
    /// buckets; native returns an exact-fit router (every (n, m, d) routes
    /// to itself, padding-free).
    fn router(&self) -> Router;

    /// Whether `key` (op + bucket) is executable on this backend.
    fn has(&self, key: &str) -> bool;

    /// Execute one op.  Input and output layouts follow the artifact
    /// manifest contract (see `python/compile/aot.py` and the op table in
    /// `crate::native`).
    fn call(&self, key: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>>;

    /// Cumulative measured IO/work counters for this backend instance
    /// (monotone; callers difference two snapshots with
    /// [`crate::obs::IoStats::delta_since`] to attribute an interval).
    /// Backends that do not measure — the PJRT engine, stubs — inherit
    /// this default and report all-zeros, which downstream consumers
    /// render as explicit zeros rather than absent series.
    fn io_stats(&self) -> crate::obs::IoStats {
        crate::obs::IoStats::default()
    }

    /// One batched Sinkhorn step block: `k` inner iterations over every
    /// *active* problem of `batch`, updating the packed shifted duals in
    /// place (wall entries and frozen problems are left untouched).
    /// `k > 1` requests the fused `k{k}_*` op semantics; callers pass
    /// `k == self.k_fused()` only when [`Self::has`] confirmed the fused op.
    ///
    /// The default walks the problems one by one through [`Self::call`] —
    /// one dispatch per problem, bitwise identical to a sequential solve by
    /// definition, so every backend supports the batched API.  Backends
    /// with a genuinely fused path (native) override this with one pool
    /// fan-out over the packed row range.
    fn lse_step_batch(
        &self,
        batch: &BatchedProblem,
        fhat: &mut [f32],
        ghat: &mut [f32],
        active: &[bool],
        k: usize,
        alternating: bool,
    ) -> Result<Vec<BatchStepOut>> {
        check_batch_state(batch, fhat, ghat, active)?;
        let sched = if alternating { "alternating" } else { "symmetric" };
        let op = if k <= 1 { format!("{sched}_step") } else { format!("k{k}_{sched}") };
        let mut outs = Vec::with_capacity(batch.len());
        for p in 0..batch.len() {
            if !active[p] {
                outs.push(BatchStepOut::default());
                continue;
            }
            let prob = batch.problem(p);
            let (rr, cr) = (batch.row_range(p), batch.col_range(p));
            let io0 = self.io_stats();
            let res = self.call(
                &op,
                &[
                    Tensor::matrix(prob.n, prob.d, prob.x.clone()),
                    Tensor::matrix(prob.m, prob.d, prob.y.clone()),
                    Tensor::vector(fhat[rr.clone()].to_vec()),
                    Tensor::vector(ghat[cr.clone()].to_vec()),
                    Tensor::vector(prob.a.clone()),
                    Tensor::vector(prob.b.clone()),
                    Tensor::scalar(prob.eps),
                ],
            )?;
            if res.len() < 4 {
                bail!("{op}: step returned {} outputs, expected 4", res.len());
            }
            fhat[rr].copy_from_slice(res[0].as_f32()?);
            ghat[cr].copy_from_slice(res[1].as_f32()?);
            outs.push(BatchStepOut {
                df: res[2].item()?,
                dg: res[3].item()?,
                io: self.io_stats().delta_since(&io0),
            });
        }
        Ok(outs)
    }

    /// Batched forward transport application: `(P V, r)` rows for every
    /// active problem, with `v` a `cols() x p_width` panel packed like the
    /// target side (wall rows of the outputs stay zero).  `p_width` must be
    /// 1 or `batch.d` — the op table's `p1`/`pd` variants.  Default: one
    /// [`Self::call`] per problem; native overrides with one fan-out.
    fn apply_batch(
        &self,
        batch: &BatchedProblem,
        fhat: &[f32],
        ghat: &[f32],
        active: &[bool],
        v: &[f32],
        p_width: usize,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let op = match p_width {
            1 => "apply_pv_p1",
            w if w == batch.d => "apply_pv_pd",
            w => bail!("apply_batch: panel width {w} is neither 1 nor d={}", batch.d),
        };
        if fhat.len() != batch.rows() || ghat.len() != batch.cols() {
            bail!("apply_batch: packed dual lengths do not match the batch");
        }
        if v.len() != batch.cols() * p_width || active.len() != batch.len() {
            bail!("apply_batch: panel/active lengths do not match the batch");
        }
        let mut pv = vec![0.0f32; batch.rows() * p_width];
        let mut r = vec![0.0f32; batch.rows()];
        for p in 0..batch.len() {
            if !active[p] {
                continue;
            }
            let prob = batch.problem(p);
            let (rr, cr) = (batch.row_range(p), batch.col_range(p));
            let res = self.call(
                op,
                &[
                    Tensor::matrix(prob.n, prob.d, prob.x.clone()),
                    Tensor::matrix(prob.m, prob.d, prob.y.clone()),
                    Tensor::vector(fhat[rr.clone()].to_vec()),
                    Tensor::vector(ghat[cr.clone()].to_vec()),
                    Tensor::vector(prob.a.clone()),
                    Tensor::vector(prob.b.clone()),
                    Tensor::matrix(prob.m, p_width, v[cr.start * p_width..cr.end * p_width].to_vec()),
                    Tensor::scalar(prob.eps),
                ],
            )?;
            if res.len() < 2 {
                bail!("{op}: apply returned {} outputs, expected 2", res.len());
            }
            pv[rr.start * p_width..rr.end * p_width].copy_from_slice(res[0].as_f32()?);
            r[rr].copy_from_slice(res[1].as_f32()?);
        }
        Ok((pv, r))
    }
}

/// Shared argument validation for [`ComputeBackend::lse_step_batch`]
/// implementations.
pub fn check_batch_state(
    batch: &BatchedProblem,
    fhat: &[f32],
    ghat: &[f32],
    active: &[bool],
) -> Result<()> {
    if fhat.len() != batch.rows() || ghat.len() != batch.cols() || active.len() != batch.len() {
        bail!(
            "batched state mismatch: fhat {} vs rows {}, ghat {} vs cols {}, active {} vs B {}",
            fhat.len(),
            batch.rows(),
            ghat.len(),
            batch.cols(),
            active.len(),
            batch.len()
        );
    }
    Ok(())
}

/// Strip the `__n{n}_m{m}_d{d}` bucket suffix from an artifact key,
/// returning the bare op name.  Keys without a suffix pass through.
pub fn op_of_key(key: &str) -> &str {
    match key.rfind("__n") {
        Some(pos) => &key[..pos],
        None => key,
    }
}

/// A repeated call with most inputs frozen: `slots` holds `Some(tensor)`
/// for static inputs and `None` for the per-call dynamic positions, filled
/// left-to-right from the `dynamics` argument of [`PreparedCall::call`].
///
/// The static tensors are materialized into the argument buffer **once at
/// construction**; each call copies only the small dynamic inputs (the
/// evolving potentials / CG iterate) into their slots.  This is the
/// backend-agnostic successor of the PJRT cached-literal hot path — the
/// per-backend upload caching can specialize behind `ComputeBackend::call`
/// without the drivers changing.  Holds a `RefCell` argument buffer, so a
/// prepared call is single-threaded by construction (like the backends'
/// actor-thread usage).
pub struct PreparedCall<'b> {
    backend: &'b dyn ComputeBackend,
    key: String,
    /// Full argument buffer: statics pre-filled, dynamic slots overwritten
    /// on every call.
    buf: std::cell::RefCell<Vec<Tensor>>,
    dynamic_slots: Vec<usize>,
}

impl<'b> PreparedCall<'b> {
    /// Prepare `key` on `backend` with `slots` holding `Some(tensor)` for
    /// each frozen static input and `None` for each per-call dynamic slot.
    pub fn new(
        backend: &'b dyn ComputeBackend,
        key: impl Into<String>,
        slots: Vec<Option<Tensor>>,
    ) -> Self {
        let dynamic_slots: Vec<usize> = slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.is_none().then_some(i))
            .collect();
        let buf: Vec<Tensor> = slots
            .into_iter()
            .map(|s| s.unwrap_or_else(|| Tensor::scalar(0.0)))
            .collect();
        Self { backend, key: key.into(), buf: std::cell::RefCell::new(buf), dynamic_slots }
    }

    /// The artifact/op key this call was prepared for.
    pub fn key(&self) -> &str {
        &self.key
    }

    /// Execute with the dynamic slots filled in order.
    pub fn call(&self, dynamics: &[Tensor]) -> Result<Vec<Tensor>> {
        if dynamics.len() != self.dynamic_slots.len() {
            return Err(anyhow!(
                "{}: prepared call expects {} dynamic inputs, got {}",
                self.key,
                self.dynamic_slots.len(),
                dynamics.len()
            ));
        }
        let mut buf = self.buf.borrow_mut();
        for (&slot, t) in self.dynamic_slots.iter().zip(dynamics) {
            buf[slot] = t.clone();
        }
        self.backend.call(&self.key, &buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_of_key_strips_bucket_suffix() {
        assert_eq!(op_of_key("alternating_step__n256_m512_d16"), "alternating_step");
        assert_eq!(op_of_key("k10_symmetric__n64_m64_d4"), "k10_symmetric");
        assert_eq!(op_of_key("marginals"), "marginals");
        // label ops keep their own underscores
        assert_eq!(op_of_key("alternating_step_label__n8_m8_d2"), "alternating_step_label");
    }
}
