//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.  Records every lowered op, its shape bucket and the exact
//! input/output layout so calls can be validated before they hit PJRT.
//! Parsed with the in-repo JSON parser (`util::json`).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: Option<String>,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct Entry {
    pub op: String,
    pub n: usize,
    pub m: usize,
    pub d: usize,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Debug)]
pub struct Manifest {
    pub version: usize,
    pub num_classes: usize,
    pub k_fused: usize,
    pub entries: HashMap<String, Entry>,
}

fn io_spec(v: &Json) -> Result<IoSpec> {
    Ok(IoSpec {
        name: v.get("name").and_then(|n| n.as_str().ok().map(str::to_string)),
        shape: v
            .req("shape")?
            .as_arr()?
            .iter()
            .map(|x| x.as_usize())
            .collect::<Result<_>>()?,
        dtype: v.req("dtype")?.as_str()?.to_string(),
    })
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let root = Json::parse(text).context("parsing manifest.json")?;
        let version = root.req("version")?.as_usize()?;
        if version != 1 {
            anyhow::bail!("unsupported manifest version {version}");
        }
        let mut entries = HashMap::new();
        for (key, e) in root.req("entries")?.as_obj()? {
            let entry = Entry {
                op: e.req("op")?.as_str()?.to_string(),
                n: e.req("n")?.as_usize()?,
                m: e.req("m")?.as_usize()?,
                d: e.req("d")?.as_usize()?,
                file: e.req("file")?.as_str()?.to_string(),
                inputs: e.req("inputs")?.as_arr()?.iter().map(io_spec).collect::<Result<_>>()?,
                outputs: e.req("outputs")?.as_arr()?.iter().map(io_spec).collect::<Result<_>>()?,
            };
            entries.insert(key.clone(), entry);
        }
        Ok(Manifest {
            version,
            num_classes: root.req("num_classes")?.as_usize()?,
            k_fused: root.req("k_fused")?.as_usize()?,
            entries,
        })
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    /// Canonical artifact key for an op at a shape bucket.
    pub fn key(op: &str, n: usize, m: usize, d: usize) -> String {
        format!("{op}__n{n}_m{m}_d{d}")
    }

    pub fn entry(&self, key: &str) -> Result<&Entry> {
        self.entries
            .get(key)
            .ok_or_else(|| anyhow!("no artifact '{key}' in manifest (rerun `make artifacts`?)"))
    }

    pub fn has(&self, key: &str) -> bool {
        self.entries.contains_key(key)
    }

    /// All (n, m, d) buckets available for `op`, sorted by padded volume.
    pub fn buckets(&self, op: &str) -> Vec<(usize, usize, usize)> {
        let mut v: Vec<_> = self
            .entries
            .values()
            .filter(|e| e.op == op)
            .map(|e| (e.n, e.m, e.d))
            .collect();
        v.sort_by_key(|&(n, m, d)| (n * m * d, n, m, d));
        v
    }

    pub fn file_path(&self, dir: &Path, key: &str) -> Result<PathBuf> {
        Ok(dir.join(&self.entry(key)?.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_format_matches_aot() {
        assert_eq!(
            Manifest::key("alternating_step", 256, 512, 16),
            "alternating_step__n256_m512_d16"
        );
    }

    #[test]
    fn parse_minimal_manifest() {
        let text = r#"{
          "version": 1, "num_classes": 20, "k_fused": 10,
          "entries": {
            "grad_x__n256_m256_d16": {
              "op": "grad_x", "n": 256, "m": 256, "d": 16,
              "file": "grad_x__n256_m256_d16.hlo.txt",
              "inputs": [{"name": "x", "shape": [256, 16], "dtype": "f32"}],
              "outputs": [{"shape": [256, 16], "dtype": "f32"}]
            }
          }
        }"#;
        let m = Manifest::parse(text).unwrap();
        assert!(m.has("grad_x__n256_m256_d16"));
        assert_eq!(m.buckets("grad_x"), vec![(256, 256, 16)]);
        let e = m.entry("grad_x__n256_m256_d16").unwrap();
        assert_eq!(e.inputs[0].shape, vec![256, 16]);
        assert_eq!(e.inputs[0].name.as_deref(), Some("x"));
    }

    #[test]
    fn rejects_wrong_version() {
        let text = r#"{"version": 9, "num_classes": 1, "k_fused": 1, "entries": {}}"#;
        assert!(Manifest::parse(text).is_err());
    }
}
