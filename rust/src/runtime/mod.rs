//! Runtime layer: the `ComputeBackend` trait, the artifact manifest, the
//! host tensor type — and, behind the `pjrt` feature, the PJRT client
//! wrapper that executes Python-lowered HLO artifacts.
//!
//! The default build has **zero** FFI/Python dependencies: ops run on
//! [`crate::native::NativeBackend`].  Enabling `--features pjrt` compiles
//! [`engine::Engine`], which loads HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them lazily on the PJRT CPU client
//! (caching the executables), and executes them with [`Tensor`] inputs.
//! `Engine` is intentionally `!Send` (PJRT handles are raw pointers); the
//! service wraps whichever backend it builds in a dedicated actor thread.

pub mod artifacts;
pub mod backend;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod tensor;

pub use artifacts::{Entry, Manifest};
pub use backend::{BatchStepOut, ComputeBackend, PreparedCall};
#[cfg(feature = "pjrt")]
pub use engine::Engine;
pub use tensor::Tensor;
