//! Runtime layer: PJRT client wrapper, artifact manifest, tensor bridge.
//!
//! `Engine` is the only place the crate touches the `xla` crate: it loads
//! HLO-text artifacts produced by `python/compile/aot.py`, compiles them
//! lazily on the PJRT CPU client (caching the executables), and executes
//! them with `Tensor` inputs.  Engine is intentionally `!Send` (PJRT handles
//! are raw pointers); the service wraps it in a dedicated actor thread.

pub mod artifacts;
pub mod engine;
pub mod tensor;

pub use artifacts::{Entry, Manifest};
pub use engine::Engine;
pub use tensor::Tensor;
