//! Host-side tensors; with `--features pjrt`, also the conversion bridge
//! to/from `xla::Literal`.

#[cfg(feature = "pjrt")]
use anyhow::anyhow;
use anyhow::{bail, Result};

/// A dense host tensor (row-major).  Only the two dtypes the artifacts use.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::I32 { shape, data }
    }

    /// Rank-0 f32 scalar (runtime parameters: eps, tau, lambda...).
    pub fn scalar(v: f32) -> Self {
        Tensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn vector(data: Vec<f32>) -> Self {
        Tensor::F32 { shape: vec![data.len()], data }
    }

    pub fn matrix(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len());
        Tensor::F32 { shape: vec![rows, cols], data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor::F32 { shape, data: vec![0.0; n] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype_name(&self) -> &'static str {
        match self {
            Tensor::F32 { .. } => "f32",
            Tensor::I32 { .. } => "i32",
        }
    }

    pub fn len(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    /// First element as f32 (for rank-0 outputs like `df`, `dg`).
    pub fn item(&self) -> Result<f32> {
        Ok(self.as_f32()?[0])
    }

    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data),
            Tensor::I32 { data, .. } => xla::Literal::vec1(data),
        };
        Ok(lit.reshape(&dims)?)
    }

    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::F32 { shape: dims, data: lit.to_vec::<f32>()? }),
            xla::ElementType::S32 => Ok(Tensor::I32 { shape: dims, data: lit.to_vec::<i32>()? }),
            other => Err(anyhow!("unsupported literal element type {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_dtypes() {
        let t = Tensor::matrix(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.dtype_name(), "f32");
        assert_eq!(t.len(), 6);
        let i = Tensor::i32(vec![4], vec![7, -1, 0, 3]);
        assert_eq!(i.dtype_name(), "i32");
        assert!(i.as_f32().is_err());
    }

    #[test]
    fn scalar_has_empty_shape() {
        let t = Tensor::scalar(0.25);
        assert!(t.shape().is_empty());
        assert_eq!(t.item().unwrap(), 0.25);
    }

    #[test]
    fn item_rejects_i32() {
        assert!(Tensor::i32(vec![1], vec![3]).item().is_err());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::matrix(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_roundtrip_i32() {
        let t = Tensor::i32(vec![4], vec![7, -1, 0, 3]);
        let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(t, back);
    }
}
