//! JSON configuration for the launcher and the service.
//!
//! Every field has a default so `repro` runs with no config file;
//! `repro --config path.json` overrides any subset (see
//! `configs/default.json` for a fully-populated example).  JSON rather
//! than TOML because the config parser is the in-repo `util::json`
//! substrate (offline build; DESIGN.md section 2).

use anyhow::{Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Config {
    /// Compute backend: "native" (default, pure Rust) or "pjrt"
    /// (HLO artifacts; requires the `pjrt` cargo feature).
    pub backend: String,
    /// Worker-thread cap for the native backend's kernel pool.  0 (default)
    /// shares the process-global pool, sized by `FLASH_SINKHORN_THREADS`
    /// (unset = one worker per core); any other value gives this deployment
    /// a private pool of exactly that width.
    pub threads: usize,
    /// Directory holding `manifest.json` + `*.hlo.txt` artifacts (pjrt).
    pub artifact_dir: String,
    pub solver: SolverSection,
    pub service: ServiceSection,
    pub hvp: HvpSection,
    pub bench: BenchSection,
}

#[derive(Debug, Clone)]
pub struct SolverSection {
    /// Maximum Sinkhorn iterations per eps level.
    pub max_iters: usize,
    /// Stop when the sup-norm potential change drops below this.
    pub tol: f32,
    /// "alternating" | "symmetric" | "auto" (auto = Table 18 crossover).
    pub schedule: String,
    /// Use the fused k-step artifact when far from the tolerance.
    pub use_fused: bool,
    /// eps-annealing factor in (0, 1]; 1.0 disables (section H.4: 0.9).
    pub anneal_factor: f32,
    /// Solve-strategy spec, e.g. "plain", "gauss", "1d+anneal:4",
    /// "gauss+anneal+newton:1e-2" (see `ot::strategy`).  Defaults from
    /// `FLASH_SINKHORN_STRATEGY` (unset = "plain"); the config keys
    /// (top-level `"strategy"` or `solver.strategy`) and the
    /// `repro solve --strategy` flag override it, in that order.
    pub strategy: String,
}

#[derive(Debug, Clone)]
pub struct ServiceSection {
    /// Max jobs coalesced into one same-bucket batch.
    pub max_batch: usize,
    /// Max time a job waits for batch-mates before dispatch (ms).
    pub max_wait_ms: u64,
    /// Bound on the pending-job queue (backpressure).
    pub queue_cap: usize,
    /// Backend actors the service shards across.  1 (the default) is the
    /// original single-actor service; N > 1 partitions the kernel pool
    /// into N slices and steals queued classes across actors.  Defaults
    /// from `FLASH_SINKHORN_ACTORS` (unset or 0 = 1); the config key and
    /// the `repro serve --actors` flag override it, in that order.
    pub actors: usize,
    /// Lower bound for the adaptive actor pool: the supervisor never
    /// parks below this many active actors.  0 (default) means `actors`
    /// — together with `actors_max = 0` that is the static pool.
    pub actors_min: usize,
    /// Upper bound for the adaptive actor pool (actor *slots* spawned).
    /// 0 (default) means `actors`; setting `actors_min < actors_max`
    /// turns elasticity on (grow on sustained queue depth, park on
    /// sustained idleness, kernel pool repartitioned on every resize).
    pub actors_max: usize,
    /// Per-tenant token refill rate, jobs/second (0 = rate limiting off).
    /// Defaults from `FLASH_SINKHORN_TENANT_RATE`; config key and the
    /// `repro serve --tenant-rate` flag override it, in that order.
    pub tenant_rate: f64,
    /// Per-tenant token-bucket burst capacity (0 = `max(tenant_rate, 1)`).
    /// Defaults from `FLASH_SINKHORN_TENANT_BURST`.
    pub tenant_burst: f64,
    /// Per-tenant cap on admitted-but-incomplete jobs (0 = off).
    /// Defaults from `FLASH_SINKHORN_TENANT_INFLIGHT`.
    pub tenant_inflight: usize,
    /// Byte budget (MiB) of the per-tenant warm-start dual cache; 0
    /// (the default) disables it, keeping serving bitwise identical to
    /// the cacheless solver.  Defaults from
    /// `FLASH_SINKHORN_WARM_CACHE_MB`; the config key and the
    /// `repro serve --warm-cache-mb` flag override it, in that order.
    pub warm_cache_mb: usize,
    /// Shape-class ceiling for the fused many-small-OT path: classes
    /// whose row envelopes satisfy `max(class_n, class_m) <=
    /// batch_threshold` have their coalesced jobs solved in **one**
    /// packed backend dispatch instead of one per job.  0 (the default)
    /// disables batching, keeping serving bitwise identical to the
    /// per-job dispatch path.  Defaults from
    /// `FLASH_SINKHORN_BATCH_THRESHOLD`; the config key and the
    /// `repro serve --batch-threshold` flag override it, in that order.
    pub batch_threshold: usize,
    /// Supervisor cadence (ms) for the adaptive actor pool.  Defaults
    /// from `FLASH_SINKHORN_TICK_MS` (unset or 0 = 25).
    pub tick_ms: u64,
    /// Consecutive busy ticks (class depth >= max_batch) before the
    /// supervisor wakes another actor.  Defaults from
    /// `FLASH_SINKHORN_GROW_AFTER_TICKS` (unset or 0 = 2).
    pub grow_after_ticks: u32,
    /// Consecutive empty ticks before the supervisor parks an actor.
    /// Defaults from `FLASH_SINKHORN_PARK_AFTER_TICKS` (unset or 0 = 2).
    pub park_after_ticks: u32,
    /// Observability mode: "off", "counters" (default — cheap atomic
    /// IO/work counters only), "trace" or "trace:N" (counters plus a
    /// bounded job-lifecycle trace ring of N events; see `obs::ObsMode`).
    /// Defaults from `FLASH_SINKHORN_OBS`; the config key overrides it.
    pub obs: String,
}

#[derive(Debug, Clone)]
pub struct HvpSection {
    /// Tikhonov damping tau for the Schur system (paper default 1e-5).
    pub tau: f32,
    /// CG relative-residual tolerance eta (paper default 1e-6).
    pub eta: f64,
    /// CG iteration cap (paper benchmarks fix K = 50).
    pub max_cg: usize,
}

#[derive(Debug, Clone)]
pub struct BenchSection {
    /// Output directory for regenerated tables/figures.
    pub out_dir: String,
    /// Repetitions per timing cell.
    pub reps: usize,
    /// Warmup runs discarded before timing.
    pub warmup: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            backend: std::env::var("FLASH_SINKHORN_BACKEND").unwrap_or_else(|_| "native".into()),
            threads: 0,
            artifact_dir: crate::artifact_dir().to_string_lossy().into_owned(),
            solver: SolverSection {
                max_iters: 1000,
                tol: 1e-4,
                schedule: "auto".into(),
                use_fused: true,
                anneal_factor: 1.0,
                strategy: std::env::var("FLASH_SINKHORN_STRATEGY")
                    .unwrap_or_else(|_| "plain".into()),
            },
            service: ServiceSection {
                max_batch: 16,
                max_wait_ms: 2,
                queue_cap: 1024,
                actors: std::env::var("FLASH_SINKHORN_ACTORS")
                    .ok()
                    .and_then(|v| v.parse::<usize>().ok())
                    .filter(|&a| a > 0)
                    .unwrap_or(1),
                actors_min: 0,
                actors_max: 0,
                tenant_rate: env_f64("FLASH_SINKHORN_TENANT_RATE"),
                tenant_burst: env_f64("FLASH_SINKHORN_TENANT_BURST"),
                tenant_inflight: std::env::var("FLASH_SINKHORN_TENANT_INFLIGHT")
                    .ok()
                    .and_then(|v| v.parse::<usize>().ok())
                    .unwrap_or(0),
                warm_cache_mb: std::env::var("FLASH_SINKHORN_WARM_CACHE_MB")
                    .ok()
                    .and_then(|v| v.parse::<usize>().ok())
                    .unwrap_or(0),
                batch_threshold: std::env::var("FLASH_SINKHORN_BATCH_THRESHOLD")
                    .ok()
                    .and_then(|v| v.parse::<usize>().ok())
                    .unwrap_or(0),
                tick_ms: env_pos_u64(
                    "FLASH_SINKHORN_TICK_MS",
                    crate::coordinator::service::DEFAULT_SUPERVISOR_TICK_MS,
                ),
                grow_after_ticks: env_pos_u64(
                    "FLASH_SINKHORN_GROW_AFTER_TICKS",
                    u64::from(crate::coordinator::service::DEFAULT_GROW_AFTER_TICKS),
                ) as u32,
                park_after_ticks: env_pos_u64(
                    "FLASH_SINKHORN_PARK_AFTER_TICKS",
                    u64::from(crate::coordinator::service::DEFAULT_PARK_AFTER_TICKS),
                ) as u32,
                obs: std::env::var("FLASH_SINKHORN_OBS")
                    .unwrap_or_else(|_| "counters".into()),
            },
            hvp: HvpSection { tau: 1e-5, eta: 1e-6, max_cg: 200 },
            bench: BenchSection { out_dir: "results".into(), reps: 3, warmup: 1 },
        }
    }
}

/// Positive u64 from the environment; unset, unparsable or zero reads as
/// `default` (the supervisor knobs have no meaningful "off").
fn env_pos_u64(var: &str, default: u64) -> u64 {
    std::env::var(var)
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .filter(|&v| v > 0)
        .unwrap_or(default)
}

/// Non-negative f64 from the environment; unset, unparsable or negative
/// reads as 0.0 (= that limit disabled).
fn env_f64(var: &str) -> f64 {
    std::env::var(var)
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .filter(|v| v.is_finite() && *v >= 0.0)
        .unwrap_or(0.0)
}

fn upd_usize(j: &Json, key: &str, slot: &mut usize) -> Result<()> {
    if let Some(v) = j.get(key) {
        *slot = v.as_usize()?;
    }
    Ok(())
}

fn upd_f32(j: &Json, key: &str, slot: &mut f32) -> Result<()> {
    if let Some(v) = j.get(key) {
        *slot = v.as_f64()? as f32;
    }
    Ok(())
}

impl Config {
    pub fn from_json(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let mut cfg = Config::default();
        if let Some(v) = j.get("backend") {
            cfg.backend = v.as_str()?.to_string();
        }
        upd_usize(&j, "threads", &mut cfg.threads)?;
        if let Some(v) = j.get("artifact_dir") {
            cfg.artifact_dir = v.as_str()?.to_string();
        }
        // top-level "strategy" is shorthand for solver.strategy (the
        // nested key, when also present, wins)
        if let Some(v) = j.get("strategy") {
            cfg.solver.strategy = v.as_str()?.to_string();
        }
        if let Some(s) = j.get("solver") {
            upd_usize(s, "max_iters", &mut cfg.solver.max_iters)?;
            upd_f32(s, "tol", &mut cfg.solver.tol)?;
            if let Some(v) = s.get("schedule") {
                cfg.solver.schedule = v.as_str()?.to_string();
            }
            if let Some(v) = s.get("use_fused") {
                cfg.solver.use_fused = v.as_bool()?;
            }
            upd_f32(s, "anneal_factor", &mut cfg.solver.anneal_factor)?;
            if let Some(v) = s.get("strategy") {
                cfg.solver.strategy = v.as_str()?.to_string();
            }
        }
        // fail at load time, not mid-solve
        crate::ot::strategy::SolveStrategy::parse(&cfg.solver.strategy)
            .with_context(|| format!("config key 'strategy' = {:?}", cfg.solver.strategy))?;
        if let Some(s) = j.get("service") {
            upd_usize(s, "max_batch", &mut cfg.service.max_batch)?;
            if let Some(v) = s.get("max_wait_ms") {
                cfg.service.max_wait_ms = v.as_usize()? as u64;
            }
            upd_usize(s, "queue_cap", &mut cfg.service.queue_cap)?;
            upd_usize(s, "actors", &mut cfg.service.actors)?;
            upd_usize(s, "actors_min", &mut cfg.service.actors_min)?;
            upd_usize(s, "actors_max", &mut cfg.service.actors_max)?;
            if let Some(v) = s.get("tenant_rate") {
                cfg.service.tenant_rate = v.as_f64()?;
            }
            if let Some(v) = s.get("tenant_burst") {
                cfg.service.tenant_burst = v.as_f64()?;
            }
            upd_usize(s, "tenant_inflight", &mut cfg.service.tenant_inflight)?;
            upd_usize(s, "warm_cache_mb", &mut cfg.service.warm_cache_mb)?;
            upd_usize(s, "batch_threshold", &mut cfg.service.batch_threshold)?;
            if let Some(v) = s.get("tick_ms") {
                cfg.service.tick_ms = v.as_usize()? as u64;
            }
            if let Some(v) = s.get("grow_after_ticks") {
                cfg.service.grow_after_ticks = v.as_usize()? as u32;
            }
            if let Some(v) = s.get("park_after_ticks") {
                cfg.service.park_after_ticks = v.as_usize()? as u32;
            }
            if let Some(v) = s.get("obs") {
                cfg.service.obs = v.as_str()?.to_string();
            }
        }
        // fail at load time, not at service spawn
        crate::obs::ObsMode::parse(&cfg.service.obs)
            .with_context(|| format!("config key 'service.obs' = {:?}", cfg.service.obs))?;
        if let Some(s) = j.get("hvp") {
            upd_f32(s, "tau", &mut cfg.hvp.tau)?;
            if let Some(v) = s.get("eta") {
                cfg.hvp.eta = v.as_f64()?;
            }
            upd_usize(s, "max_cg", &mut cfg.hvp.max_cg)?;
        }
        if let Some(s) = j.get("bench") {
            if let Some(v) = s.get("out_dir") {
                cfg.bench.out_dir = v.as_str()?.to_string();
            }
            upd_usize(s, "reps", &mut cfg.bench.reps)?;
            upd_usize(s, "warmup", &mut cfg.bench.warmup)?;
        }
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<Self> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading config {path}"))?;
        Self::from_json(&text).with_context(|| format!("parsing config {path}"))
    }

    pub fn load_or_default(path: Option<&str>) -> Result<Self> {
        match path {
            Some(p) => Self::load(p),
            None => Ok(Self::default()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partial_config_fills_defaults() {
        let cfg = Config::from_json(r#"{"solver": {"max_iters": 7}}"#).unwrap();
        assert_eq!(cfg.solver.max_iters, 7);
        assert_eq!(cfg.solver.schedule, "auto");
        assert_eq!(cfg.service.max_batch, 16);
    }

    #[test]
    fn backend_override_parses() {
        let cfg = Config::from_json(r#"{"backend": "pjrt"}"#).unwrap();
        assert_eq!(cfg.backend, "pjrt");
    }

    #[test]
    fn threads_knob_parses_and_defaults_to_shared_pool() {
        assert_eq!(Config::from_json("{}").unwrap().threads, 0);
        assert_eq!(Config::from_json(r#"{"threads": 6}"#).unwrap().threads, 6);
        assert!(Config::from_json(r#"{"threads": -1}"#).is_err());
    }

    #[test]
    fn actors_knob_parses_and_defaults_to_one() {
        // (FLASH_SINKHORN_ACTORS is not set in the test environment)
        assert!(Config::from_json("{}").unwrap().service.actors >= 1);
        assert_eq!(
            Config::from_json(r#"{"service": {"actors": 4}}"#).unwrap().service.actors,
            4
        );
        assert!(Config::from_json(r#"{"service": {"actors": -2}}"#).is_err());
    }

    #[test]
    fn adaptive_and_tenant_knobs_parse_and_default_off() {
        // (FLASH_SINKHORN_TENANT_* are not set in the test environment)
        let d = Config::from_json("{}").unwrap();
        assert_eq!((d.service.actors_min, d.service.actors_max), (0, 0));
        assert_eq!(d.service.tenant_rate, 0.0);
        assert_eq!(d.service.tenant_burst, 0.0);
        assert_eq!(d.service.tenant_inflight, 0);
        let cfg = Config::from_json(
            r#"{"service": {"actors_min": 2, "actors_max": 8,
                 "tenant_rate": 12.5, "tenant_burst": 4, "tenant_inflight": 3}}"#,
        )
        .unwrap();
        assert_eq!(cfg.service.actors_min, 2);
        assert_eq!(cfg.service.actors_max, 8);
        assert_eq!(cfg.service.tenant_rate, 12.5);
        assert_eq!(cfg.service.tenant_burst, 4.0);
        assert_eq!(cfg.service.tenant_inflight, 3);
        assert!(Config::from_json(r#"{"service": {"actors_min": -1}}"#).is_err());
        assert!(Config::from_json(r#"{"service": {"tenant_rate": "fast"}}"#).is_err());
    }

    #[test]
    fn warm_cache_and_supervisor_knobs_parse_with_current_defaults() {
        // (FLASH_SINKHORN_WARM_CACHE_MB / _TICK_MS / _*_AFTER_TICKS are
        // not set in the test environment)
        let d = Config::from_json("{}").unwrap();
        assert_eq!(d.service.warm_cache_mb, 0, "cache must default off");
        assert_eq!(d.service.tick_ms, 25);
        assert_eq!(d.service.grow_after_ticks, 2);
        assert_eq!(d.service.park_after_ticks, 2);
        let cfg = Config::from_json(
            r#"{"service": {"warm_cache_mb": 64, "tick_ms": 5,
                 "grow_after_ticks": 3, "park_after_ticks": 7}}"#,
        )
        .unwrap();
        assert_eq!(cfg.service.warm_cache_mb, 64);
        assert_eq!(cfg.service.tick_ms, 5);
        assert_eq!(cfg.service.grow_after_ticks, 3);
        assert_eq!(cfg.service.park_after_ticks, 7);
        assert!(Config::from_json(r#"{"service": {"warm_cache_mb": -1}}"#).is_err());
        assert!(Config::from_json(r#"{"service": {"tick_ms": "fast"}}"#).is_err());
    }

    #[test]
    fn batch_threshold_parses_and_defaults_off() {
        // (FLASH_SINKHORN_BATCH_THRESHOLD is not set in the test environment)
        assert_eq!(
            Config::from_json("{}").unwrap().service.batch_threshold,
            0,
            "batching must default off (bitwise-identical serving)"
        );
        let cfg =
            Config::from_json(r#"{"service": {"batch_threshold": 256}}"#).unwrap();
        assert_eq!(cfg.service.batch_threshold, 256);
        assert!(Config::from_json(r#"{"service": {"batch_threshold": -1}}"#).is_err());
    }

    #[test]
    fn obs_knob_parses_and_validates_at_load_time() {
        // (FLASH_SINKHORN_OBS is not set in the test environment)
        assert_eq!(Config::from_json("{}").unwrap().service.obs, "counters");
        let cfg = Config::from_json(r#"{"service": {"obs": "trace:128"}}"#).unwrap();
        assert_eq!(cfg.service.obs, "trace:128");
        assert_eq!(Config::from_json(r#"{"service": {"obs": "off"}}"#).unwrap().service.obs, "off");
        let err = Config::from_json(r#"{"service": {"obs": "verbose"}}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("service.obs"), "{err}");
    }

    #[test]
    fn strategy_key_parses_at_both_levels_and_validates() {
        // (FLASH_SINKHORN_STRATEGY is not set in the test environment)
        assert_eq!(Config::from_json("{}").unwrap().solver.strategy, "plain");
        let top = Config::from_json(r#"{"strategy": "gauss+anneal:3"}"#).unwrap();
        assert_eq!(top.solver.strategy, "gauss+anneal:3");
        // the nested key wins over the top-level shorthand
        let both = Config::from_json(
            r#"{"strategy": "gauss", "solver": {"strategy": "1d+newton"}}"#,
        )
        .unwrap();
        assert_eq!(both.solver.strategy, "1d+newton");
        // bad specs fail at load time
        let err = Config::from_json(r#"{"strategy": "warp"}"#).unwrap_err().to_string();
        assert!(err.contains("strategy"), "{err}");
        assert!(Config::from_json(r#"{"solver": {"strategy": "anneal:0"}}"#).is_err());
    }

    #[test]
    fn full_override() {
        let cfg = Config::from_json(
            r#"{"artifact_dir": "/tmp/a",
                "solver": {"schedule": "symmetric", "anneal_factor": 0.9, "use_fused": false},
                "service": {"max_batch": 4, "max_wait_ms": 10, "queue_cap": 8},
                "hvp": {"tau": 1e-7, "eta": 1e-8, "max_cg": 33},
                "bench": {"out_dir": "r2", "reps": 9, "warmup": 0}}"#,
        )
        .unwrap();
        assert_eq!(cfg.artifact_dir, "/tmp/a");
        assert_eq!(cfg.solver.schedule, "symmetric");
        assert!(!cfg.solver.use_fused);
        assert_eq!(cfg.service.queue_cap, 8);
        assert_eq!(cfg.hvp.max_cg, 33);
        assert_eq!(cfg.bench.reps, 9);
    }

    #[test]
    fn bad_json_is_an_error() {
        assert!(Config::from_json("{solver: 3}").is_err());
        assert!(Config::from_json(r#"{"solver": {"max_iters": -2}}"#).is_err());
    }
}
