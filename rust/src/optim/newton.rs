//! Newton-CG step with Armijo backtracking (paper section H.4: initial
//! step 10.0, reduction 0.5, sufficient-decrease c = 0.1, CG <= 100 iters
//! at tol 1e-6, Tikhonov tau = 1e-5 on the inner system).

use crate::hvp::cg::cg_solve;

#[derive(Debug, Clone)]
pub struct NewtonOutcome {
    pub params: Vec<f32>,
    pub loss: f64,
    pub step_size: f64,
    pub cg_iters: usize,
    pub accepted: bool,
    pub loss_evals: usize,
}

/// One damped-Newton step: solve (H + tau I) p = -grad by CG (matvec given
/// by `hvp`), then Armijo backtrack on `loss_at`.
#[allow(clippy::too_many_arguments)]
pub fn armijo_newton_step<H, L, E>(
    params: &[f32],
    grad: &[f32],
    loss0: f64,
    mut hvp: H,
    mut loss_at: L,
    tau: f32,
    cg_tol: f64,
    cg_max: usize,
    step0: f64,
    backtrack: f64,
    c_armijo: f64,
    max_backtracks: usize,
) -> Result<NewtonOutcome, E>
where
    H: FnMut(&[f32]) -> Result<Vec<f32>, E>,
    L: FnMut(&[f32]) -> Result<f64, E>,
{
    let dim = params.len();
    let neg_grad: Vec<f32> = grad.iter().map(|g| -g).collect();
    let cg = cg_solve(
        |v: &[f32]| -> Result<Vec<f32>, E> {
            let mut hv = hvp(v)?;
            for i in 0..dim {
                hv[i] += tau * v[i];
            }
            Ok(hv)
        },
        &neg_grad,
        cg_tol,
        cg_max,
    )?;
    let dir = cg.x;
    let slope: f64 = grad.iter().zip(&dir).map(|(&g, &p)| g as f64 * p as f64).sum();
    // if CG returned a non-descent direction (indefinite H), fall back to -grad
    let (dir, slope) = if slope < 0.0 {
        (dir, slope)
    } else {
        let s: f64 = grad.iter().map(|&g| -(g as f64) * g as f64).sum();
        (neg_grad.clone(), s)
    };

    let mut t = step0;
    let mut evals = 0;
    for _ in 0..max_backtracks {
        let cand: Vec<f32> = params
            .iter()
            .zip(&dir)
            .map(|(&w, &p)| w + (t * p as f64) as f32)
            .collect();
        let l = loss_at(&cand)?;
        evals += 1;
        if l <= loss0 + c_armijo * t * slope {
            return Ok(NewtonOutcome {
                params: cand,
                loss: l,
                step_size: t,
                cg_iters: cg.iters,
                accepted: true,
                loss_evals: evals,
            });
        }
        t *= backtrack;
    }
    Ok(NewtonOutcome {
        params: params.to_vec(),
        loss: loss0,
        step_size: 0.0,
        cg_iters: cg.iters,
        accepted: false,
        loss_evals: evals,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn newton_solves_quadratic_in_one_step() {
        // f(w) = 1/2 w^T A w - b^T w with A = diag(1, 4)
        let a = [1.0f32, 4.0];
        let b = [1.0f32, 8.0]; // minimum at (1, 2)
        let w = [0.0f32, 0.0];
        let grad: Vec<f32> = (0..2).map(|i| a[i] * w[i] - b[i]).collect();
        let loss = |p: &[f32]| -> Result<f64, ()> {
            Ok((0..2)
                .map(|i| 0.5 * a[i] as f64 * (p[i] as f64).powi(2) - b[i] as f64 * p[i] as f64)
                .sum())
        };
        let out = armijo_newton_step(
            &w,
            &grad,
            loss(&w).unwrap(),
            |v: &[f32]| Ok::<_, ()>(vec![a[0] * v[0], a[1] * v[1]]),
            loss,
            0.0,
            1e-10,
            50,
            1.0,
            0.5,
            0.1,
            20,
        )
        .unwrap();
        assert!(out.accepted);
        assert!((out.params[0] - 1.0).abs() < 1e-4);
        assert!((out.params[1] - 2.0).abs() < 1e-4);
    }

    #[test]
    fn falls_back_to_gradient_on_indefinite_hessian() {
        // H = -I: CG direction is ascent; must fall back to -grad and
        // still decrease f(w) = |w|_1-ish convex surrogate.
        let w = [1.0f32];
        let grad = [2.0f32]; // f = w^2 at w=1
        let out = armijo_newton_step(
            &w,
            &grad,
            1.0,
            |v: &[f32]| Ok::<_, ()>(vec![-v[0]]),
            |p: &[f32]| Ok((p[0] as f64).powi(2)),
            0.0,
            1e-8,
            10,
            1.0,
            0.5,
            0.1,
            30,
        )
        .unwrap();
        assert!(out.accepted);
        assert!(out.loss < 1.0);
    }
}
