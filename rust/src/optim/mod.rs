//! First- and second-order optimizers driving EOT objectives (paper
//! section 4.2 / H.4): full-batch Adam for saddle regions, Newton-CG with
//! Armijo backtracking once local convexity is detected.

pub mod adam;
pub mod newton;

pub use adam::Adam;
pub use newton::{armijo_newton_step, NewtonOutcome};
