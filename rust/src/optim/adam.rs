//! Full-batch Adam (paper section H.4: lr 0.03, betas (0.9, 0.999)).
//! Full-batch by design: the saddle detector needs a deterministic
//! trajectory and a stable curvature signal (see the paper's "Why
//! full-batch Adam?" discussion).

#[derive(Debug, Clone)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u32,
}

impl Adam {
    pub fn new(dim: usize, lr: f32) -> Self {
        Self { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, m: vec![0.0; dim], v: vec![0.0; dim], t: 0 }
    }

    /// In-place parameter update from a gradient.
    pub fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), grad.len());
        assert_eq!(params.len(), self.m.len());
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * grad[i];
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * grad[i] * grad[i];
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            params[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }

    pub fn reset(&mut self) {
        self.m.iter_mut().for_each(|x| *x = 0.0);
        self.v.iter_mut().for_each(|x| *x = 0.0);
        self.t = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        // f(w) = 1/2 |w - c|^2
        let c = [3.0f32, -2.0, 0.5];
        let mut w = [0.0f32; 3];
        let mut opt = Adam::new(3, 0.1);
        for _ in 0..500 {
            let g: Vec<f32> = w.iter().zip(&c).map(|(wi, ci)| wi - ci).collect();
            opt.step(&mut w, &g);
        }
        for (wi, ci) in w.iter().zip(&c) {
            assert!((wi - ci).abs() < 1e-2, "{wi} vs {ci}");
        }
    }

    #[test]
    fn first_step_size_is_lr() {
        // classic Adam property: |first update| ~ lr regardless of grad scale
        let mut w = [0.0f32];
        let mut opt = Adam::new(1, 0.03);
        opt.step(&mut w, &[1234.5]);
        assert!((w[0].abs() - 0.03).abs() < 1e-4, "{}", w[0]);
    }
}
