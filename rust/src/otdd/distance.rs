//! Label-augmented Sinkhorn solves and the debiased OTDD distance.

use anyhow::{bail, Result};

use crate::coordinator::router::{BucketCtx, Router};
use crate::data::labeled::LabeledDataset;
use crate::ot::problem::{sqnorms, OtProblem};
use crate::ot::solver::Potentials;
use crate::runtime::{ComputeBackend, Tensor};

/// An EOT instance under the OTDD cost.  Labels index the joint class-
/// distance matrix `w` of side `v` (dataset-B classes are pre-shifted).
#[derive(Clone)]
pub struct LabelProblem {
    pub x: Vec<f32>,
    pub y: Vec<f32>,
    pub a: Vec<f32>,
    pub b: Vec<f32>,
    pub li: Vec<i32>,
    pub lj: Vec<i32>,
    /// joint class-distance matrix, row-major (v x v).
    pub w: Vec<f32>,
    pub v: usize,
    pub n: usize,
    pub m: usize,
    pub d: usize,
    pub lam1: f32,
    pub lam2: f32,
    pub eps: f32,
}

pub struct LabelSolver<'e> {
    backend: &'e dyn ComputeBackend,
    router: Router,
    pub max_iters: usize,
    pub tol: f32,
}

impl<'e> LabelSolver<'e> {
    pub fn new(backend: &'e dyn ComputeBackend, max_iters: usize, tol: f32) -> Self {
        let router = backend.router();
        Self { backend, router, max_iters, tol }
    }

    fn ctx_and_labels(&self, p: &LabelProblem) -> Result<(BucketCtx, Tensor, Tensor, Tensor)> {
        if let Some(v_expected) = self.backend.num_classes() {
            if p.v != v_expected {
                bail!("label matrix side {} != backend num_classes {}", p.v, v_expected);
            }
        }
        let bucket = self.router.select_label(p.n, p.m, p.d)?;
        let base = OtProblem::new(
            p.x.clone(), p.y.clone(), p.a.clone(), p.b.clone(), p.n, p.m, p.d, p.eps,
        )?;
        let ctx = BucketCtx::with_bucket(bucket, &base);
        let mut li = p.li.clone();
        li.resize(bucket.n, 0); // padded rows have a = 0: label value is inert
        let mut lj = p.lj.clone();
        lj.resize(bucket.m, 0);
        Ok((
            ctx,
            Tensor::i32(vec![bucket.n], li),
            Tensor::i32(vec![bucket.m], lj),
            Tensor::matrix(p.v, p.v, p.w.clone()),
        ))
    }

    /// Solve with the alternating label-step artifact.  Potentials are in
    /// the lam1-scaled shift: fhat = f - lam1 |x|^2.
    pub fn solve(&self, p: &LabelProblem) -> Result<(Potentials, usize, f64)> {
        let (ctx, li_t, lj_t, w_t) = self.ctx_and_labels(p)?;
        let alpha = sqnorms(&p.x, p.n, p.d);
        let beta = sqnorms(&p.y, p.m, p.d);
        let mut fhat = vec![0.0f32; ctx.bucket.n];
        let mut ghat = vec![0.0f32; ctx.bucket.m];
        for i in 0..p.n {
            fhat[i] = -p.lam1 * alpha[i];
        }
        for j in 0..p.m {
            ghat[j] = -p.lam1 * beta[j];
        }
        let key = ctx.key("alternating_step_label");
        let mut iters = 0;
        let mut delta = f32::INFINITY;
        while iters < self.max_iters && delta > self.tol {
            let outs = self.backend.call(
                &key,
                &[
                    ctx.x.clone(),
                    ctx.y.clone(),
                    Tensor::vector(fhat.clone()),
                    Tensor::vector(ghat.clone()),
                    ctx.a.clone(),
                    ctx.b.clone(),
                    li_t.clone(),
                    lj_t.clone(),
                    w_t.clone(),
                    Tensor::scalar(p.lam1),
                    Tensor::scalar(p.lam2),
                    Tensor::scalar(p.eps),
                ],
            )?;
            fhat = outs[0].as_f32()?.to_vec();
            ghat = outs[1].as_f32()?.to_vec();
            delta = outs[2].item()?.max(outs[3].item()?);
            iters += 1;
        }
        let pot = Potentials { fhat: fhat[..p.n].to_vec(), ghat: ghat[..p.m].to_vec() };
        // dual cost with the lam1-scaled shift
        let mut cost = 0.0f64;
        for i in 0..p.n {
            cost += p.a[i] as f64 * (pot.fhat[i] + p.lam1 * alpha[i]) as f64;
        }
        for j in 0..p.m {
            cost += p.b[j] as f64 * (pot.ghat[j] + p.lam1 * beta[j]) as f64;
        }
        Ok((pot, iters, cost))
    }

    /// Gradient of the label-augmented OT w.r.t. X (the W term is
    /// x-independent): 2 lam1 (diag(r) X - P Y).
    pub fn grad_x(&self, p: &LabelProblem, pot: &Potentials) -> Result<Vec<f32>> {
        let (ctx, li_t, lj_t, w_t) = self.ctx_and_labels(p)?;
        let outs = self.backend.call(
            &ctx.key("grad_x_label"),
            &[
                ctx.x.clone(),
                ctx.y.clone(),
                ctx.pad_n(&pot.fhat, 0.0),
                ctx.pad_m(&pot.ghat, 0.0),
                ctx.a.clone(),
                ctx.b.clone(),
                li_t,
                lj_t,
                w_t,
                Tensor::scalar(p.lam1),
                Tensor::scalar(p.lam2),
                Tensor::scalar(p.eps),
            ],
        )?;
        ctx.slice_n_mat(&outs[0], p.d)
    }
}

#[derive(Debug, Clone)]
pub struct OtddReport {
    pub distance: f64,
    pub ot_ab: f64,
    pub ot_aa: f64,
    pub ot_bb: f64,
    pub total_iters: usize,
    pub w_matrix_solves: usize,
}

/// Full OTDD distance between two labeled datasets: builds the joint class
/// matrix W (inner OT solves), then the three debiased label-cost solves.
#[allow(clippy::too_many_arguments)]
pub fn otdd_distance(
    backend: &dyn ComputeBackend,
    ds_a: &LabeledDataset,
    ds_b: &LabeledDataset,
    lam1: f32,
    lam2: f32,
    eps: f32,
    max_iters: usize,
    tol: f32,
) -> Result<OtddReport> {
    let (w, w_solves) = super::wmatrix::build_w_matrix(backend, ds_a, ds_b, eps)?;
    let v = ds_a.num_classes + ds_b.num_classes;
    let solver = LabelSolver::new(backend, max_iters, tol);
    let shift = ds_a.num_classes as i32;
    let lj_b: Vec<i32> = ds_b.labels.iter().map(|&l| l + shift).collect();
    let uni = |n: usize| vec![1.0 / n as f32; n];

    let mk = |x: &LabeledDataset, xl: &[i32], y: &LabeledDataset, yl: &[i32]| LabelProblem {
        x: x.x.clone(),
        y: y.x.clone(),
        a: uni(x.n),
        b: uni(y.n),
        li: xl.to_vec(),
        lj: yl.to_vec(),
        w: w.clone(),
        v,
        n: x.n,
        m: y.n,
        d: x.d,
        lam1,
        lam2,
        eps,
    };

    let (_, i1, ot_ab) = solver.solve(&mk(ds_a, &ds_a.labels, ds_b, &lj_b))?;
    let (_, i2, ot_aa) = solver.solve(&mk(ds_a, &ds_a.labels, ds_a, &ds_a.labels))?;
    let (_, i3, ot_bb) = {
        let p = LabelProblem {
            x: ds_b.x.clone(),
            y: ds_b.x.clone(),
            a: uni(ds_b.n),
            b: uni(ds_b.n),
            li: lj_b.clone(),
            lj: lj_b.clone(),
            w: w.clone(),
            v,
            n: ds_b.n,
            m: ds_b.n,
            d: ds_b.d,
            lam1,
            lam2,
            eps,
        };
        solver.solve(&p)?
    };
    Ok(OtddReport {
        distance: ot_ab - 0.5 * ot_aa - 0.5 * ot_bb,
        ot_ab,
        ot_aa,
        ot_bb,
        total_iters: i1 + i2 + i3,
        w_matrix_solves: w_solves,
    })
}
