//! Class-to-class distance matrix W (paper eq. 33): the joint
//! (V1 + V2)^2 matrix of Wasserstein distances between class-conditional
//! distributions, each entry an inner (debiased) Sinkhorn solve -- the
//! "many inner OT problems" the paper's OTDD setup precomputes.

use anyhow::Result;

use crate::data::labeled::LabeledDataset;
use crate::ot::divergence::sinkhorn_divergence;
use crate::ot::solver::{Schedule, SolverConfig};
use crate::runtime::ComputeBackend;

/// Max points per class used in inner solves (subsampling cap; the paper's
/// OTDD library defaults to similar caps for the label metric).
pub const CLASS_CAP: usize = 128;

/// Build the joint W: block [W11 W12; W12^T W22], where each entry is the
/// *debiased* entropic divergence between class clouds (so diagonals are
/// ~0, as a metric's should be).  Returns (W flat (v x v), #inner solves).
pub fn build_w_matrix(
    backend: &dyn ComputeBackend,
    ds_a: &LabeledDataset,
    ds_b: &LabeledDataset,
    eps: f32,
) -> Result<(Vec<f32>, usize)> {
    let v1 = ds_a.num_classes;
    let v2 = ds_b.num_classes;
    let v = v1 + v2;
    let d = ds_a.d;
    let cfg = SolverConfig {
        max_iters: 200,
        tol: 1e-4,
        schedule: Schedule::Alternating,
        use_fused: true,
        anneal_factor: 1.0,
        prepared: true,
        ..SolverConfig::default()
    };

    // collect capped class clouds once
    let clouds: Vec<(Vec<f32>, usize)> = (0..v)
        .map(|c| {
            let (ds, cls) = if c < v1 { (ds_a, c as i32) } else { (ds_b, (c - v1) as i32) };
            let full = ds.class_cloud(cls);
            let n = (full.len() / d).min(CLASS_CAP);
            (full[..n * d].to_vec(), n)
        })
        .collect();

    let mut w = vec![0.0f32; v * v];
    let mut solves = 0;
    for c1 in 0..v {
        for c2 in (c1 + 1)..v {
            let (x, n) = &clouds[c1];
            let (y, m) = &clouds[c2];
            let a = vec![1.0 / *n as f32; *n];
            let b = vec![1.0 / *m as f32; *m];
            let rep = sinkhorn_divergence(backend, &cfg, x, y, &a, &b, *n, *m, d, eps)?;
            solves += 3;
            w[c1 * v + c2] = rep.value as f32;
            w[c2 * v + c1] = rep.value as f32;
        }
    }
    Ok((w, solves))
}
