//! OTDD gradient flow (paper eq. 34 / Figure 4): dataset adaptation by
//! descending the debiased label-augmented Sinkhorn divergence,
//! X <- X - eta * grad_X S_eps(X, Y).

use anyhow::Result;

use crate::data::labeled::LabeledDataset;
use crate::runtime::ComputeBackend;

use super::distance::{LabelProblem, LabelSolver};

#[derive(Debug, Clone)]
pub struct FlowReport {
    /// divergence value per step (should decrease).
    pub values: Vec<f64>,
    /// wall seconds per step.
    pub step_seconds: Vec<f64>,
    /// final adapted source points.
    pub x_final: Vec<f32>,
}

/// Run `steps` flow iterations with learning rate `eta`.  The class matrix
/// `w` is precomputed by the caller (held fixed across the flow, as in the
/// paper's timing runs; recompute it outside if classes drift far).
#[allow(clippy::too_many_arguments)]
pub fn gradient_flow(
    backend: &dyn ComputeBackend,
    ds_a: &LabeledDataset,
    ds_b: &LabeledDataset,
    w: &[f32],
    lam1: f32,
    lam2: f32,
    eps: f32,
    eta: f32,
    steps: usize,
    max_iters: usize,
) -> Result<FlowReport> {
    let v = ds_a.num_classes + ds_b.num_classes;
    let shift = ds_a.num_classes as i32;
    let lj_b: Vec<i32> = ds_b.labels.iter().map(|&l| l + shift).collect();
    let solver = LabelSolver::new(backend, max_iters, 1e-4);
    let uni = |n: usize| vec![1.0 / n as f32; n];

    let mut x = ds_a.x.clone();
    let (n, m, d) = (ds_a.n, ds_b.n, ds_a.d);
    let mut values = Vec::with_capacity(steps);
    let mut step_seconds = Vec::with_capacity(steps);

    for _ in 0..steps {
        let t0 = std::time::Instant::now();
        let mk = |xs: &[f32], ys: &[f32], li: &[i32], lj: &[i32], nn: usize, mm: usize| LabelProblem {
            x: xs.to_vec(),
            y: ys.to_vec(),
            a: uni(nn),
            b: uni(mm),
            li: li.to_vec(),
            lj: lj.to_vec(),
            w: w.to_vec(),
            v,
            n: nn,
            m: mm,
            d,
            lam1,
            lam2,
            eps,
        };
        // three solves (debiased): xy, xx, yy
        let p_xy = mk(&x, &ds_b.x, &ds_a.labels, &lj_b, n, m);
        let (pot_xy, _, ot_xy) = solver.solve(&p_xy)?;
        let p_xx = mk(&x, &x, &ds_a.labels, &ds_a.labels, n, n);
        let (pot_xx, _, ot_xx) = solver.solve(&p_xx)?;
        let p_yy = mk(&ds_b.x, &ds_b.x, &lj_b, &lj_b, m, m);
        let (_, _, ot_yy) = solver.solve(&p_yy)?;
        values.push(ot_xy - 0.5 * ot_xx - 0.5 * ot_yy);

        // debiased gradient: grad_1 OT(x, y) - grad_1 OT(x, x)
        let g_xy = solver.grad_x(&p_xy, &pot_xy)?;
        let g_xx = solver.grad_x(&p_xx, &pot_xx)?;
        for k in 0..n * d {
            x[k] -= eta * (g_xy[k] - g_xx[k]);
        }
        step_seconds.push(t0.elapsed().as_secs_f64());
    }
    Ok(FlowReport { values, step_seconds, x_final: x })
}
