//! Optimal Transport Dataset Distance (paper section 4.2 / H.3):
//! label-augmented cost C = lam1 |x - y|^2 + lam2 W[l_i, l_j], with the
//! (V, V) class-distance matrix gathered on the fly *inside* the streaming
//! kernels -- the capability KeOps-style backends lack (paper Table 24).

pub mod distance;
pub mod flow;
pub mod wmatrix;

pub use distance::{otdd_distance, LabelProblem, LabelSolver, OtddReport};
pub use flow::{gradient_flow, FlowReport};
pub use wmatrix::build_w_matrix;
